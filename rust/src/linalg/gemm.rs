//! Packed, register-tiled, cache-blocked GEMM on row-major buffers —
//! the single hottest primitive in the repository. Every TT/CP
//! contraction in `projections::`, the TT×TT group kernel in
//! `tensor::batch`, flat-index query scoring and batched LSH hashing all
//! reduce to the entry points here.
//!
//! # Kernel architecture
//!
//! BLIS-style structure with three levels:
//!
//! * **Microkernel** — an `MR×NR` (4×8) register tile updated over one
//!   `KC`-length slice of the reduction dimension. Two implementations
//!   share one accumulation order: an explicitly vectorized AVX2 kernel
//!   (`core::arch` intrinsics, 8 × 4-lane f64 accumulators, selected at
//!   runtime via `is_x86_feature_detected!`) and a fixed-width scalar
//!   kernel that LLVM unrolls (the fallback on other CPUs). Neither uses
//!   FMA contraction — plain mul-then-add — so both produce bit-identical
//!   results.
//! * **Packing** — A is packed into `MR`-row micro-panels
//!   (`apack[p·MR + lane]`), B into `NR`-column micro-panels
//!   (`bpack[p·NR + lane]`), so the microkernel streams both operands
//!   contiguously. Edge tiles are zero-padded in the packs; the padded
//!   lanes are computed into scratch and never stored. The A-side pack
//!   reads through a generic *gather* accessor and the C-side store
//!   through a *row-offset* map, which is what lets `tensor::batch` fuse
//!   its TT×TT regroup permutes into the pack prologue / store epilogue
//!   ([`matmul_gather_scatter_acc`]) and `Matrix::t_matmul` multiply by a
//!   transpose without materializing it. (This packing is the f64 serving
//!   analogue of the f32 AOT layouts in `runtime::pack` — see the
//!   cross-reference there.)
//! * **Cache blocking** — loops `jc(NC=512) → kb(KC=256) → ic(MC=64)`
//!   keep the B panel in L2 and the A panel in L1 across the microkernel
//!   sweep. Shapes too small to amortize packing take a simple blocked
//!   loop with the same accumulation order.
//!
//! # Determinism contract
//!
//! Every output element is computed as
//! `c[i][j] + Σ_p a[i][p]·b[p][j]` with the sum accumulated **in
//! ascending `p` order as one sequential IEEE chain** (the register tile
//! is loaded from `c`, updated in ascending `p`, and stored back —
//! load/store round-trips are exact, so cache blocking never
//! reassociates). The chain per element therefore depends only on `k`
//! and the operand values, never on `m`, `n`, the dispatch path
//! (simple / packed / AVX2 / `n = 1`), or the worker count of the
//! parallel path — which is what upholds the repository-wide
//! batched-vs-per-item, sharded-vs-unsharded and row-subset bit-identity
//! gates (`rust/tests/gemm_kernel_props.rs`,
//! `rust/tests/projection_batch_props.rs`). The kernel never skips zero
//! operands (the seed's small-`n` path dropped `a == 0.0` terms, which
//! would swallow `0·NaN`/`0·∞`); NaN/Inf propagate exactly as the naive
//! triple loop would.
//!
//! # Parallelism
//!
//! [`matmul_acc`] splits large products ([`PAR_MIN_FLOPS`]) into
//! contiguous `MR`-aligned row panels across scoped threads
//! ([`gemm_threads`], env `TRP_GEMM_THREADS`). Each output row is
//! produced by exactly one thread running the identical serial kernel,
//! so the partitioning is rank-stable and the result is bit-identical
//! for every worker count (property-tested for {1, 2, 4}).

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Microkernel register-tile rows.
pub const MR: usize = 4;
/// Microkernel register-tile columns (one AVX2 cache line pair).
pub const NR: usize = 8;
/// Reduction-dimension block: one A micro-panel column stays in L1.
const KC: usize = 256;
/// Row block: the packed A panel (`MC × KC` f64 = 128 KiB) stays in L2.
const MC: usize = 64;
/// Column block: the packed B panel (`KC × NC` f64 = 1 MiB) stays in L3.
const NC: usize = 512;
/// Below this many multiply-adds the packing overhead dominates and the
/// simple loop wins.
const PACK_MIN_FLOPS: usize = 16 * 1024;
/// Minimum multiply-adds before the row-panel parallel path engages.
const PAR_MIN_FLOPS: usize = 1 << 20;

/// Worker count for the parallel row-panel path. 0 = uninitialized.
static GEMM_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Worker count used by [`matmul_acc`] for large products: the
/// `TRP_GEMM_THREADS` env var when set, else available parallelism.
pub fn gemm_threads() -> usize {
    let v = GEMM_THREADS.load(Ordering::Relaxed);
    if v != 0 {
        return v;
    }
    let v = std::env::var("TRP_GEMM_THREADS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
        .filter(|&n| n >= 1)
        .unwrap_or_else(|| std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1));
    GEMM_THREADS.store(v, Ordering::Relaxed);
    v
}

/// Override the worker count for the parallel GEMM path (process-wide).
/// Results are bit-identical for every count by the determinism
/// contract; this only tunes throughput.
pub fn set_gemm_threads(n: usize) {
    GEMM_THREADS.store(n.max(1), Ordering::Relaxed);
}

/// `c = a · b` where `a` is `m×k`, `b` is `k×n`, `c` is `m×n` (row-major).
pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "a size");
    assert_eq!(b.len(), k * n, "b size");
    assert_eq!(c.len(), m * n, "c size");
    c.fill(0.0);
    matmul_acc(a, b, c, m, k, n);
}

/// `c += a · b` (same layout as [`matmul_into`]). Large products split
/// row panels across [`gemm_threads`] workers (bit-identical to serial).
pub fn matmul_acc(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
    matmul_acc_with_threads(a, b, c, m, k, n, gemm_threads());
}

/// [`matmul_acc`] with an explicit worker count — the test hook for the
/// thread-count bit-identity gate, and the inner entry of the default.
///
/// This is the funnel every packed-operand GEMM passes through, so it is
/// also where the observability layer's shape profile hooks in: when
/// `obs::gemm_profiling` is on, the call's wall time and flop count are
/// aggregated by shape bucket. The numeric path is untouched either way
/// (the disabled cost is one relaxed atomic load).
pub fn matmul_acc_with_threads(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    if crate::obs::gemm_profiling_enabled() {
        let t0 = std::time::Instant::now();
        matmul_acc_threads_impl(a, b, c, m, k, n, threads);
        crate::obs::gemm_record(m, k, n, t0.elapsed().as_micros() as u64);
        return;
    }
    matmul_acc_threads_impl(a, b, c, m, k, n, threads);
}

fn matmul_acc_threads_impl(
    a: &[f64],
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    threads: usize,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    // One worker per MR-aligned row panel at most; below the flop floor
    // the spawn overhead outweighs the split.
    let panels = m.div_ceil(MR);
    let t = threads.max(1).min(panels);
    if t <= 1 || m * k * n < PAR_MIN_FLOPS {
        gemm_serial(&|i, p| a[i * k + p], b, c, m, k, n, &|i| i * n);
        return;
    }
    // Rank-stable partition: contiguous MR-aligned row chunks in order.
    // Each output row is owned by exactly one worker running the same
    // serial kernel, so every element's accumulation chain is the one
    // the contract fixes — identical for every `t`.
    let per_rows = panels.div_ceil(t) * MR;
    std::thread::scope(|s| {
        let mut rest_a = a;
        let mut rest_c = &mut c[..];
        while !rest_c.is_empty() {
            let rows = per_rows.min(rest_c.len() / n);
            let (ca, rc) = rest_c.split_at_mut(rows * n);
            let (aa, ra) = rest_a.split_at(rows * k);
            rest_c = rc;
            rest_a = ra;
            s.spawn(move || {
                gemm_serial(&|i, p| aa[i * k + p], b, ca, rows, k, n, &|i| i * n);
            });
        }
    });
}

/// Fused-permute GEMM: `c[row_off(i)..row_off(i)+n] += Σ_p a_at(i,p)·b[p·n..]`
/// for `i < m`. The A operand is *gathered* element-wise through `a_at`
/// during packing (prologue) and each C row is *scattered* to
/// `row_off(i)` at store time (epilogue) — this is how the TT×TT group
/// kernel folds its two regroup permutes into the GEMM itself and how
/// [`super::Matrix::t_matmul`] multiplies by a transpose in place.
///
/// Contract: distinct `i` must map to non-overlapping C rows. The
/// accumulation order per element is identical to [`matmul_acc`]
/// (serial; the row scatter makes panel splitting pointless at the
/// shapes this serves).
#[allow(clippy::too_many_arguments)]
pub fn matmul_gather_scatter_acc(
    a_at: impl Fn(usize, usize) -> f64,
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    row_off: impl Fn(usize) -> usize,
) {
    debug_assert_eq!(b.len(), k * n);
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    if crate::obs::gemm_profiling_enabled() {
        let t0 = std::time::Instant::now();
        gemm_serial(&a_at, b, c, m, k, n, &row_off);
        crate::obs::gemm_record(m, k, n, t0.elapsed().as_micros() as u64);
        return;
    }
    gemm_serial(&a_at, b, c, m, k, n, &row_off);
}

/// Allocating wrapper around [`matmul_into`].
pub fn matmul(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
    let mut c = vec![0.0; m * n];
    matmul_into(a, b, &mut c, m, k, n);
    c
}

/// Matrix-vector product `y = a · x` for row-major `a` (`m×k`) — the
/// `n = 1` case of the one GEMM kernel (deduplicated from the seed's
/// standalone dot-product loop; the accumulation chain is unchanged).
pub fn matvec(a: &[f64], x: &[f64], m: usize, k: usize) -> Vec<f64> {
    assert_eq!(a.len(), m * k);
    assert_eq!(x.len(), k);
    matmul(a, x, m, k, 1)
}

/// Serial GEMM driver: dispatches between the `n = 1` dot path, the
/// simple blocked loop and the packed microkernel path. All three
/// implement the module-level accumulation chain exactly.
fn gemm_serial<A, R>(a_at: &A, b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize, row_off: &R)
where
    A: Fn(usize, usize) -> f64,
    R: Fn(usize) -> usize,
{
    if m == 0 || k == 0 || n == 0 {
        return;
    }
    if n == 1 {
        // Dot-product shape: the packed path has nothing to reuse.
        for i in 0..m {
            let co = row_off(i);
            let mut acc = c[co];
            for (p, &xv) in b.iter().enumerate().take(k) {
                acc += a_at(i, p) * xv;
            }
            c[co] = acc;
        }
        return;
    }
    if m < MR || n < NR || m * k * n < PACK_MIN_FLOPS {
        gemm_simple(a_at, b, c, m, k, n, row_off);
    } else {
        with_pack_scratch(|apack, bpack| {
            gemm_packed(a_at, b, c, m, k, n, row_off, apack, bpack);
        });
    }
}

/// Unpacked fallback for shapes below the packing threshold: row-major
/// `i-p-j` loops, direct ascending-`p` accumulation into C.
fn gemm_simple<A, R>(a_at: &A, b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize, row_off: &R)
where
    A: Fn(usize, usize) -> f64,
    R: Fn(usize) -> usize,
{
    for i in 0..m {
        let co = row_off(i);
        let crow = &mut c[co..co + n];
        for (p, brow) in b.chunks_exact(n).enumerate() {
            let av = a_at(i, p);
            for (cj, &bj) in crow.iter_mut().zip(brow) {
                *cj += av * bj;
            }
        }
    }
}

/// Per-thread packing scratch: one A panel (`MC×KC`) and one B panel
/// (`KC×NC`), reused across calls so steady-state GEMM allocates
/// nothing.
fn with_pack_scratch<T>(f: impl FnOnce(&mut Vec<f64>, &mut Vec<f64>) -> T) -> T {
    thread_local! {
        static SCRATCH: RefCell<(Vec<f64>, Vec<f64>)> = const { RefCell::new((Vec::new(), Vec::new())) };
    }
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        let (apack, bpack) = &mut *s;
        f(apack, bpack)
    })
}

/// Pack rows `i0..i0+mc` of A (via the gather accessor) for reduction
/// block `kb..kb+kc` into `MR`-row micro-panels:
/// `apack[tile·(kc·MR) + p·MR + lane] = A[i0 + tile·MR + lane][kb + p]`,
/// lanes past the edge zero-padded.
fn pack_a<A: Fn(usize, usize) -> f64>(
    a_at: &A,
    apack: &mut [f64],
    i0: usize,
    mc: usize,
    kb: usize,
    kc: usize,
) {
    for (tile, panel) in apack.chunks_exact_mut(kc * MR).enumerate().take(mc.div_ceil(MR)) {
        let i = i0 + tile * MR;
        let mr = MR.min(i0 + mc - i);
        for (p, dst) in panel.chunks_exact_mut(MR).enumerate() {
            for (lane, d) in dst.iter_mut().enumerate().take(mr) {
                *d = a_at(i + lane, kb + p);
            }
            for d in dst.iter_mut().skip(mr) {
                *d = 0.0;
            }
        }
    }
}

/// Pack columns `j0..j0+nc` of row-major B (`k×n`) for reduction block
/// `kb..kb+kc` into `NR`-column micro-panels:
/// `bpack[tile·(kc·NR) + p·NR + lane] = B[kb + p][j0 + tile·NR + lane]`,
/// lanes past the edge zero-padded.
fn pack_b(b: &[f64], n: usize, bpack: &mut [f64], j0: usize, nc: usize, kb: usize, kc: usize) {
    for (tile, panel) in bpack.chunks_exact_mut(kc * NR).enumerate().take(nc.div_ceil(NR)) {
        let j = j0 + tile * NR;
        let nr = NR.min(j0 + nc - j);
        for (p, dst) in panel.chunks_exact_mut(NR).enumerate() {
            let src = &b[(kb + p) * n + j..(kb + p) * n + j + nr];
            dst[..nr].copy_from_slice(src);
            for d in dst.iter_mut().skip(nr) {
                *d = 0.0;
            }
        }
    }
}

/// Packed path: `jc(NC) → kb(KC) → ic(MC) → jr(NR) → ir(MR)` blocking
/// around the register microkernel. The register tile is loaded from C
/// before each `KC` slice and stored after, so the per-element chain
/// stays the single ascending-`p` sequence of the contract.
#[allow(clippy::too_many_arguments)]
fn gemm_packed<A, R>(
    a_at: &A,
    b: &[f64],
    c: &mut [f64],
    m: usize,
    k: usize,
    n: usize,
    row_off: &R,
    apack: &mut Vec<f64>,
    bpack: &mut Vec<f64>,
) where
    A: Fn(usize, usize) -> f64,
    R: Fn(usize) -> usize,
{
    apack.resize(MC * KC, 0.0);
    bpack.resize(NC * KC, 0.0);
    let mut tile = [0.0f64; MR * NR];
    let mut jc = 0;
    while jc < n {
        let nc = NC.min(n - jc);
        let jtiles = nc.div_ceil(NR);
        let mut kb = 0;
        while kb < k {
            let kc = KC.min(k - kb);
            pack_b(b, n, bpack, jc, nc, kb, kc);
            let mut ic = 0;
            while ic < m {
                let mc = MC.min(m - ic);
                pack_a(a_at, apack, ic, mc, kb, kc);
                let itiles = mc.div_ceil(MR);
                for jt in 0..jtiles {
                    let j = jc + jt * NR;
                    let nr = NR.min(jc + nc - j);
                    let bp = &bpack[jt * kc * NR..(jt + 1) * kc * NR];
                    for it in 0..itiles {
                        let i = ic + it * MR;
                        let mr = MR.min(ic + mc - i);
                        let ap = &apack[it * kc * MR..(it + 1) * kc * MR];
                        // Prologue: load the valid C entries into the
                        // register tile (zeros in padded lanes — their
                        // results are discarded below).
                        tile.fill(0.0);
                        for (ir, trow) in tile.chunks_exact_mut(NR).enumerate().take(mr) {
                            let co = row_off(i + ir) + j;
                            trow[..nr].copy_from_slice(&c[co..co + nr]);
                        }
                        kernel_4x8(ap, bp, kc, &mut tile);
                        // Epilogue: scatter the valid lanes back.
                        for (ir, trow) in tile.chunks_exact(NR).enumerate().take(mr) {
                            let co = row_off(i + ir) + j;
                            c[co..co + nr].copy_from_slice(&trow[..nr]);
                        }
                    }
                }
                ic += mc;
            }
            kb += kc;
        }
        jc += nc;
    }
}

/// Microkernel dispatch: AVX2 when the CPU has it (checked once),
/// otherwise the scalar fixed-width kernel. Both compute the identical
/// ascending-`p` mul-add chain per tile element.
#[inline]
fn kernel_4x8(ap: &[f64], bp: &[f64], kc: usize, tile: &mut [f64; MR * NR]) {
    #[cfg(target_arch = "x86_64")]
    if avx2_available() {
        // SAFETY: AVX2 presence verified at runtime by `avx2_available`.
        unsafe { kernel_4x8_avx2(ap, bp, kc, tile) };
        return;
    }
    kernel_4x8_scalar(ap, bp, kc, tile);
}

/// Cached runtime CPU-feature probe.
#[cfg(target_arch = "x86_64")]
fn avx2_available() -> bool {
    use std::sync::OnceLock;
    static AVX2: OnceLock<bool> = OnceLock::new();
    *AVX2.get_or_init(|| std::arch::is_x86_feature_detected!("avx2"))
}

/// Scalar `MR×NR` microkernel over packed panels: fixed-width inner
/// loops LLVM fully unrolls. Plain mul-then-add keeps it bit-identical
/// to the AVX2 kernel.
fn kernel_4x8_scalar(ap: &[f64], bp: &[f64], kc: usize, tile: &mut [f64; MR * NR]) {
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    for (arow, brow) in ap.chunks_exact(MR).zip(bp.chunks_exact(NR)).take(kc) {
        for (trow, &av) in tile.chunks_exact_mut(NR).zip(arow) {
            for (t, &bv) in trow.iter_mut().zip(brow) {
                *t += av * bv;
            }
        }
    }
}

/// AVX2 `MR×NR` microkernel: 8 ymm accumulators (4 rows × 2 vectors),
/// one broadcast per A lane, explicit `vmulpd`+`vaddpd` (no FMA — FMA's
/// single rounding would diverge from the scalar kernel and break the
/// cross-path determinism contract).
// SAFETY: callers must (1) only call this when AVX2 is available (the
// `kernel_4x8` dispatcher probes at runtime) and (2) pass panels with
// `ap.len() >= kc * MR` and `bp.len() >= kc * NR` (the packing routines
// allocate exactly that, and the debug_assert re-checks). All raw-pointer
// arithmetic below stays inside those bounds: the A/B cursors advance by
// MR/NR per k step for `kc` steps, and the tile pointer covers the fixed
// MR*NR accumulator array. `loadu`/`storeu` make no alignment assumption.
#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn kernel_4x8_avx2(ap: &[f64], bp: &[f64], kc: usize, tile: &mut [f64; MR * NR]) {
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_loadu_pd, _mm256_mul_pd, _mm256_set1_pd, _mm256_storeu_pd,
    };
    debug_assert!(ap.len() >= kc * MR && bp.len() >= kc * NR);
    let t = tile.as_mut_ptr();
    let mut acc00 = _mm256_loadu_pd(t);
    let mut acc01 = _mm256_loadu_pd(t.add(4));
    let mut acc10 = _mm256_loadu_pd(t.add(8));
    let mut acc11 = _mm256_loadu_pd(t.add(12));
    let mut acc20 = _mm256_loadu_pd(t.add(16));
    let mut acc21 = _mm256_loadu_pd(t.add(20));
    let mut acc30 = _mm256_loadu_pd(t.add(24));
    let mut acc31 = _mm256_loadu_pd(t.add(28));
    let mut a = ap.as_ptr();
    let mut b = bp.as_ptr();
    for _ in 0..kc {
        let b0 = _mm256_loadu_pd(b);
        let b1 = _mm256_loadu_pd(b.add(4));
        let a0 = _mm256_set1_pd(*a);
        acc00 = _mm256_add_pd(acc00, _mm256_mul_pd(a0, b0));
        acc01 = _mm256_add_pd(acc01, _mm256_mul_pd(a0, b1));
        let a1 = _mm256_set1_pd(*a.add(1));
        acc10 = _mm256_add_pd(acc10, _mm256_mul_pd(a1, b0));
        acc11 = _mm256_add_pd(acc11, _mm256_mul_pd(a1, b1));
        let a2 = _mm256_set1_pd(*a.add(2));
        acc20 = _mm256_add_pd(acc20, _mm256_mul_pd(a2, b0));
        acc21 = _mm256_add_pd(acc21, _mm256_mul_pd(a2, b1));
        let a3 = _mm256_set1_pd(*a.add(3));
        acc30 = _mm256_add_pd(acc30, _mm256_mul_pd(a3, b0));
        acc31 = _mm256_add_pd(acc31, _mm256_mul_pd(a3, b1));
        a = a.add(MR);
        b = b.add(NR);
    }
    _mm256_storeu_pd(t, acc00);
    _mm256_storeu_pd(t.add(4), acc01);
    _mm256_storeu_pd(t.add(8), acc10);
    _mm256_storeu_pd(t.add(12), acc11);
    _mm256_storeu_pd(t.add(16), acc20);
    _mm256_storeu_pd(t.add(20), acc21);
    _mm256_storeu_pd(t.add(24), acc30);
    _mm256_storeu_pd(t.add(28), acc31);
}

/// The PR 5 scalar kernel, frozen verbatim as the baseline the
/// `kernel_bench` micro-benchmark (and its CI smoke job) measures the
/// packed kernel against. Not used by any production path. Note it
/// keeps the seed's `a == 0.0` skip in the small-`n` branch — the NaN
/// swallowing the live kernel explicitly dropped.
pub mod reference {
    /// Reduction-dimension tile of the frozen kernel.
    const K_BLK: usize = 64;
    /// Output-column tile of the frozen kernel.
    const J_BLK: usize = 256;

    /// `c = a · b` through the frozen PR 5 path.
    pub fn matmul_into(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
        assert_eq!(a.len(), m * k, "a size");
        assert_eq!(b.len(), k * n, "b size");
        assert_eq!(c.len(), m * n, "c size");
        c.fill(0.0);
        matmul_acc(a, b, c, m, k, n);
    }

    /// `c += a · b` through the frozen PR 5 path.
    pub fn matmul_acc(a: &[f64], b: &[f64], c: &mut [f64], m: usize, k: usize, n: usize) {
        debug_assert_eq!(a.len(), m * k);
        debug_assert_eq!(b.len(), k * n);
        debug_assert_eq!(c.len(), m * n);
        if m == 0 || k == 0 || n == 0 {
            return;
        }
        if n <= 8 || k <= 8 {
            for i in 0..m {
                let arow = &a[i * k..(i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for (p, &av) in arow.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let brow = &b[p * n..(p + 1) * n];
                    for j in 0..n {
                        crow[j] += av * brow[j];
                    }
                }
            }
            return;
        }
        let mut kb = 0;
        while kb < k {
            let kend = (kb + K_BLK).min(k);
            let mut jb = 0;
            while jb < n {
                let jend = (jb + J_BLK).min(n);
                for i in 0..m {
                    let arow = &a[i * k..(i + 1) * k];
                    let crow = &mut c[i * n + jb..i * n + jend];
                    for p in kb..kend {
                        let av = arow[p];
                        let brow = &b[p * n + jb..p * n + jend];
                        for (cj, bj) in crow.iter_mut().zip(brow) {
                            *cj += av * bj;
                        }
                    }
                }
                jb = jend;
            }
            kb = kend;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    /// Naive reference used to validate the blocked kernel.
    fn matmul_naive(a: &[f64], b: &[f64], m: usize, k: usize, n: usize) -> Vec<f64> {
        let mut c = vec![0.0; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0.0;
                for p in 0..k {
                    acc += a[i * k + p] * b[p * n + j];
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_naive_on_random_shapes() {
        let mut rng = Rng::seed_from(12);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 2),
            (7, 8, 9),
            (16, 64, 16),
            (33, 129, 257),  // crosses KC and every edge-tile case
            (2, 300, 5),     // small-n with large k
            (64, 512, 64),   // multiple KC blocks through the packed path
            (65, 300, 513),  // crosses MC/NC with edge tiles on all sides
        ] {
            let a = rng.gaussian_vec(m * k, 1.0);
            let b = rng.gaussian_vec(k * n, 1.0);
            let c = matmul(&a, &b, m, k, n);
            let r = matmul_naive(&a, &b, m, k, n);
            assert!(super::super::rel_err(&c, &r) < 1e-12, "shape {m}x{k}x{n}");
        }
    }

    #[test]
    fn acc_accumulates() {
        let a = [1.0, 0.0, 0.0, 1.0];
        let b = [2.0, 0.0, 0.0, 2.0];
        let mut c = vec![1.0; 4];
        matmul_acc(&a, &b, &mut c, 2, 2, 2);
        assert_eq!(c, vec![3.0, 1.0, 1.0, 3.0]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::seed_from(8);
        let (m, k) = (17, 43);
        let a = rng.gaussian_vec(m * k, 1.0);
        let x = rng.gaussian_vec(k, 1.0);
        let y = matvec(&a, &x, m, k);
        let y2 = matmul(&a, &x, m, k, 1);
        // Same entry point (n = 1 case) — bit-identical, not just close.
        for (u, v) in y.iter().zip(&y2) {
            assert_eq!(u.to_bits(), v.to_bits());
        }
    }

    #[test]
    fn empty_dims_are_noops() {
        let c = matmul(&[], &[], 0, 0, 0);
        assert!(c.is_empty());
        let c = matmul(&[], &[], 0, 3, 0);
        assert!(c.is_empty());
    }

    #[test]
    fn packing_round_trips() {
        // pack_a: every (row, p) of the source block appears at its
        // micro-panel slot; padded lanes are zero.
        let mut rng = Rng::seed_from(21);
        let (m, k) = (11usize, 19usize);
        let a = rng.gaussian_vec(m * k, 1.0);
        let (i0, mc, kb, kc) = (3usize, 7usize, 4usize, 13usize);
        let mut apack = vec![f64::NAN; mc.div_ceil(MR) * kc * MR];
        pack_a(&|i, p| a[i * k + p], &mut apack, i0, mc, kb, kc);
        for tile in 0..mc.div_ceil(MR) {
            for p in 0..kc {
                for lane in 0..MR {
                    let got = apack[tile * kc * MR + p * MR + lane];
                    let row = i0 + tile * MR + lane;
                    if row < i0 + mc {
                        assert_eq!(got.to_bits(), a[row * k + kb + p].to_bits());
                    } else {
                        assert_eq!(got, 0.0, "padded lane must be zero");
                    }
                }
            }
        }
        // pack_b: same property on the column side.
        let (kdim, n) = (17usize, 21usize);
        let b = rng.gaussian_vec(kdim * n, 1.0);
        let (j0, nc, kb, kc) = (5usize, 13usize, 2usize, 11usize);
        let mut bpack = vec![f64::NAN; nc.div_ceil(NR) * kc * NR];
        pack_b(&b, n, &mut bpack, j0, nc, kb, kc);
        for tile in 0..nc.div_ceil(NR) {
            for p in 0..kc {
                for lane in 0..NR {
                    let got = bpack[tile * kc * NR + p * NR + lane];
                    let col = j0 + tile * NR + lane;
                    if col < j0 + nc {
                        assert_eq!(got.to_bits(), b[(kb + p) * n + col].to_bits());
                    } else {
                        assert_eq!(got, 0.0, "padded lane must be zero");
                    }
                }
            }
        }
    }

    #[test]
    fn scalar_and_simd_microkernels_are_bit_identical() {
        // On machines without AVX2 this degenerates to scalar-vs-scalar
        // (still a valid determinism check of the dispatch wrapper).
        let mut rng = Rng::seed_from(22);
        let kc = 37;
        let ap = rng.gaussian_vec(kc * MR, 1.0);
        let bp = rng.gaussian_vec(kc * NR, 1.0);
        let seed: Vec<f64> = rng.gaussian_vec(MR * NR, 1.0);
        let mut t_dispatch = [0.0; MR * NR];
        let mut t_scalar = [0.0; MR * NR];
        t_dispatch.copy_from_slice(&seed);
        t_scalar.copy_from_slice(&seed);
        kernel_4x8(&ap, &bp, kc, &mut t_dispatch);
        kernel_4x8_scalar(&ap, &bp, kc, &mut t_scalar);
        for (x, y) in t_dispatch.iter().zip(&t_scalar) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn dispatch_paths_are_bit_identical() {
        // The same (k, A-row, B) data pushed through shapes that land in
        // the simple, packed and n=1 paths must agree bitwise per the
        // determinism contract: chains depend only on k and operands.
        let mut rng = Rng::seed_from(23);
        // 5·60·9 = 2700 multiply-adds: below PACK_MIN_FLOPS, so the base
        // shape runs the simple loop.
        let (m, k, n) = (5usize, 60usize, 9usize);
        let a = rng.gaussian_vec(m * k, 1.0);
        let b = rng.gaussian_vec(k * n, 1.0);
        // Widen by replicating rows until the packed path engages
        // (16·5·60·9 = 43200 > PACK_MIN_FLOPS), then compare the shared
        // rows: the chain depends only on k and operands, not m.
        let reps = 16;
        let mut awide = Vec::with_capacity(reps * a.len());
        for _ in 0..reps {
            awide.extend_from_slice(&a);
        }
        let wide = matmul(&awide, &b, reps * m, k, n);
        let small = matmul(&a, &b, m, k, n);
        for (x, y) in wide[..m * n].iter().zip(&small) {
            assert_eq!(x.to_bits(), y.to_bits(), "packed vs simple path");
        }
        // Column-subset invariance: n=1 slices must match the full GEMM.
        for j in [0usize, n - 1] {
            let bcol: Vec<f64> = (0..k).map(|p| b[p * n + j]).collect();
            let y = matvec(&a, &bcol, m, k);
            for i in 0..m {
                assert_eq!(y[i].to_bits(), small[i * n + j].to_bits(), "n=1 vs full, col {j}");
            }
        }
    }

    #[test]
    fn nan_propagates_through_zero_operands() {
        // 0 · NaN must reach the output (the seed's small-n path skipped
        // a == 0.0 and swallowed it; the kernel now never skips).
        let a = [0.0, 1.0];
        let b = [f64::NAN, 2.0];
        let c = matmul(&a, &b, 1, 2, 1);
        assert!(c[0].is_nan(), "0·NaN must propagate, got {}", c[0]);
        let y = matvec(&a, &b, 1, 2);
        assert!(y[0].is_nan());
        // Inf likewise: 0 · ∞ = NaN per IEEE.
        let b = [f64::INFINITY, 2.0];
        let c = matmul(&a, &b, 1, 2, 1);
        assert!(c[0].is_nan(), "0·∞ must produce NaN, got {}", c[0]);
        // The frozen reference keeps the historical skip (documented).
        let mut cref = vec![0.0; 1];
        reference::matmul_into(&[0.0, 1.0], &[f64::NAN, 2.0], &mut cref, 1, 2, 1);
        assert!(!cref[0].is_nan(), "reference baseline documents the old skip");
    }

    #[test]
    fn parallel_is_bit_identical_across_worker_counts() {
        let mut rng = Rng::seed_from(24);
        // Big enough to cross PAR_MIN_FLOPS so the split actually runs.
        let (m, k, n) = (96usize, 128usize, 96usize);
        let a = rng.gaussian_vec(m * k, 1.0);
        let b = rng.gaussian_vec(k * n, 1.0);
        let mut base = vec![0.0; m * n];
        matmul_acc_with_threads(&a, &b, &mut base, m, k, n, 1);
        for threads in [2usize, 4] {
            let mut c = vec![0.0; m * n];
            matmul_acc_with_threads(&a, &b, &mut c, m, k, n, threads);
            for (x, y) in c.iter().zip(&base) {
                assert_eq!(x.to_bits(), y.to_bits(), "threads={threads}");
            }
        }
    }

    #[test]
    fn gather_scatter_matches_plain() {
        // Gathered-transpose A and scattered (reversed-row) C must equal
        // the materialized equivalent bitwise.
        let mut rng = Rng::seed_from(25);
        let (m, k, n) = (13usize, 29usize, 11usize);
        let at = rng.gaussian_vec(k * m, 1.0); // k×m, gathered as its transpose
        let b = rng.gaussian_vec(k * n, 1.0);
        let a: Vec<f64> = (0..m * k).map(|idx| at[(idx % k) * m + idx / k]).collect();
        let plain = matmul(&a, &b, m, k, n);
        let mut scat = vec![0.0; m * n];
        matmul_gather_scatter_acc(
            |i, p| at[p * m + i],
            &b,
            &mut scat,
            m,
            k,
            n,
            |i| (m - 1 - i) * n,
        );
        for i in 0..m {
            for j in 0..n {
                assert_eq!(
                    scat[(m - 1 - i) * n + j].to_bits(),
                    plain[i * n + j].to_bits(),
                    "row {i} col {j}"
                );
            }
        }
    }

    #[test]
    fn reference_matches_new_kernel_numerically() {
        let mut rng = Rng::seed_from(26);
        for &(m, k, n) in &[(5usize, 40usize, 9usize), (32, 200, 48)] {
            let a = rng.gaussian_vec(m * k, 1.0);
            let b = rng.gaussian_vec(k * n, 1.0);
            let new = matmul(&a, &b, m, k, n);
            let mut old = vec![0.0; m * n];
            reference::matmul_into(&a, &b, &mut old, m, k, n);
            assert!(super::super::rel_err(&new, &old) < 1e-12, "shape {m}x{k}x{n}");
        }
    }
}
