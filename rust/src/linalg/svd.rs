//! One-sided Jacobi SVD.
//!
//! Needed by TT-SVD (decomposing dense tensors into TT format, used by the
//! image experiments) and TT-rounding. One-sided Jacobi is simple, robust
//! and accurate for the small-to-medium matrices that arise from TT
//! matricizations (`Rd × R'` with `R, R' ≤ ~100`).
//!
//! For an `m×n` input (any aspect ratio) [`svd`] returns `U` (`m×p`),
//! `σ` (length `p`) and `V` (`n×p`) with `A ≈ U·diag(σ)·Vᵀ`, `p = min(m,n)`,
//! singular values sorted descending.

use super::{qr, Matrix};

/// Result of a singular value decomposition.
#[derive(Debug, Clone)]
pub struct Svd {
    /// Left singular vectors, `m × p`.
    pub u: Matrix,
    /// Singular values, descending, length `p`.
    pub s: Vec<f64>,
    /// Right singular vectors, `n × p` (i.e. `A ≈ U diag(s) Vᵀ`).
    pub v: Matrix,
}

impl Svd {
    /// Reconstruct `U diag(s) Vᵀ`.
    pub fn reconstruct(&self) -> Matrix {
        let p = self.s.len();
        let mut us = self.u.clone();
        for i in 0..us.rows() {
            for j in 0..p {
                us[(i, j)] *= self.s[j];
            }
        }
        us.matmul(&self.v.transpose())
    }

    /// Truncate to the leading `r` components.
    pub fn truncate(&self, r: usize) -> Svd {
        let r = r.min(self.s.len());
        Svd {
            u: self.u.leading_cols(r),
            s: self.s[..r].to_vec(),
            v: self.v.leading_cols(r),
        }
    }

    /// Smallest rank whose discarded tail has Frobenius norm ≤ `eps * ‖A‖`.
    pub fn rank_for_tolerance(&self, eps: f64) -> usize {
        let total: f64 = self.s.iter().map(|x| x * x).sum();
        if total == 0.0 {
            return 0;
        }
        let budget = eps * eps * total;
        let mut tail = 0.0;
        for r in (0..self.s.len()).rev() {
            tail += self.s[r] * self.s[r];
            if tail > budget {
                return r + 1;
            }
        }
        0
    }
}

/// One-sided Jacobi SVD (with a QR pre-reduction for tall matrices).
pub fn svd(a: &Matrix) -> Svd {
    let m = a.rows();
    let n = a.cols();
    if m < n {
        // SVD of Aᵀ and swap factors.
        let t = svd(&a.transpose());
        return Svd { u: t.v, s: t.s, v: t.u };
    }
    // Tall case: QR first so Jacobi runs on an n×n matrix.
    if m > n {
        let (q, r) = qr(a);
        let inner = svd(&r);
        return Svd { u: q.matmul(&inner.u), s: inner.s, v: inner.v };
    }

    // Square one-sided Jacobi: rotate columns of W = A·J₁·J₂… until all
    // column pairs are orthogonal; then σ_j = ‖w_j‖, U = W·diag(1/σ), V = ∏J.
    let mut w = a.clone();
    let mut v = Matrix::identity(n);
    let eps = 1e-14;
    let max_sweeps = 60;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n {
            for q_ in (p + 1)..n {
                // Gram entries for the column pair (p, q).
                let mut app = 0.0;
                let mut aqq = 0.0;
                let mut apq = 0.0;
                for i in 0..n {
                    let wp = w[(i, p)];
                    let wq = w[(i, q_)];
                    app += wp * wp;
                    aqq += wq * wq;
                    apq += wp * wq;
                }
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off = off.max(apq.abs() / (app * aqq).sqrt().max(1e-300));
                // Jacobi rotation annihilating the (p,q) Gram entry.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let wp = w[(i, p)];
                    let wq = w[(i, q_)];
                    w[(i, p)] = c * wp - s * wq;
                    w[(i, q_)] = s * wp + c * wq;
                }
                for i in 0..n {
                    let vp = v[(i, p)];
                    let vq = v[(i, q_)];
                    v[(i, p)] = c * vp - s * vq;
                    v[(i, q_)] = s * vp + c * vq;
                }
            }
        }
        if off < eps * 10.0 {
            break;
        }
    }

    // Extract singular values and normalize columns of W into U.
    let mut s: Vec<f64> = (0..n)
        .map(|j| (0..n).map(|i| w[(i, j)] * w[(i, j)]).sum::<f64>().sqrt())
        .collect();
    let mut u = w;
    for j in 0..n {
        if s[j] > 1e-300 {
            let inv = 1.0 / s[j];
            for i in 0..n {
                u[(i, j)] *= inv;
            }
        }
    }
    // Sort descending by singular value.
    let mut order: Vec<usize> = (0..n).collect();
    // NaN-safe descending order (total_cmp, reversed operands) —
    // identical to the old partial_cmp sort on finite spectra.
    order.sort_by(|&a, &b| s[b].total_cmp(&s[a]));
    let mut u_sorted = Matrix::zeros(n, n);
    let mut v_sorted = Matrix::zeros(n, n);
    let mut s_sorted = vec![0.0; n];
    for (new_j, &old_j) in order.iter().enumerate() {
        s_sorted[new_j] = s[old_j];
        for i in 0..n {
            u_sorted[(i, new_j)] = u[(i, old_j)];
            v_sorted[(i, new_j)] = v[(i, old_j)];
        }
    }
    s = s_sorted;
    Svd { u: u_sorted, s, v: v_sorted }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::rng::Rng;

    fn check_svd(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::seed_from(seed);
        let a = Matrix::from_vec(m, n, rng.gaussian_vec(m * n, 1.0));
        let d = svd(&a);
        let p = m.min(n);
        assert_eq!(d.u.rows(), m);
        assert_eq!(d.u.cols(), p);
        assert_eq!(d.v.rows(), n);
        assert_eq!(d.s.len(), p);
        // Reconstruction.
        let rec = d.reconstruct();
        assert!(rel_err(rec.data(), a.data()) < 1e-9, "recon {m}x{n}");
        // Orthogonality.
        let utu = d.u.transpose().matmul(&d.u);
        assert!(rel_err(utu.data(), Matrix::identity(p).data()) < 1e-9);
        let vtv = d.v.transpose().matmul(&d.v);
        assert!(rel_err(vtv.data(), Matrix::identity(p).data()) < 1e-9);
        // Descending singular values, all nonnegative.
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-12);
        }
        assert!(d.s.iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn shapes() {
        check_svd(6, 6, 1);
        check_svd(10, 4, 2);
        check_svd(4, 10, 3);
        check_svd(1, 1, 4);
        check_svd(30, 30, 5);
    }

    #[test]
    fn known_diagonal() {
        let a = Matrix::from_rows(&[&[3.0, 0.0], &[0.0, 2.0]]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-12);
        assert!((d.s[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn low_rank_input_detected() {
        // Rank-1 outer product.
        let mut rng = Rng::seed_from(9);
        let u = rng.gaussian_vec(12, 1.0);
        let v = rng.gaussian_vec(7, 1.0);
        let mut a = Matrix::zeros(12, 7);
        for i in 0..12 {
            for j in 0..7 {
                a[(i, j)] = u[i] * v[j];
            }
        }
        let d = svd(&a);
        assert!(d.s[1] < 1e-9 * d.s[0]);
        assert_eq!(d.rank_for_tolerance(1e-8), 1);
    }

    #[test]
    fn truncate_keeps_best_approximation() {
        let mut rng = Rng::seed_from(13);
        let a = Matrix::from_vec(8, 8, rng.gaussian_vec(64, 1.0));
        let d = svd(&a);
        let t = d.truncate(3);
        // Eckart-Young: the absolute error equals the dropped tail's norm.
        let rec = t.reconstruct();
        let err_abs: f64 = rec
            .data()
            .iter()
            .zip(a.data())
            .map(|(x, y)| (x - y) * (x - y))
            .sum::<f64>()
            .sqrt();
        let tail: f64 = d.s[3..].iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!((err_abs - tail).abs() < 1e-8, "err={err_abs} tail={tail}");
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(3, 5);
        let d = svd(&a);
        assert!(d.s.iter().all(|&x| x == 0.0));
    }
}
