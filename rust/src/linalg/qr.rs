//! Thin Householder QR decomposition.
//!
//! Used by TT left/right-orthogonalization (a pre-step of TT-rounding).
//! For an `m×n` input with `m ≥ n` it returns `Q` (`m×n`, orthonormal
//! columns) and `R` (`n×n`, upper triangular) with `A = Q·R`; for `m < n`
//! it returns the full `m×m` `Q` and `m×n` `R`.

use super::Matrix;

/// Householder QR. Returns `(q, r)` with `a = q·r`.
pub fn qr(a: &Matrix) -> (Matrix, Matrix) {
    let m = a.rows();
    let n = a.cols();
    let p = m.min(n);
    // Work on a column-major copy of A for contiguous column access.
    let mut r = a.clone();
    // Householder vectors, one per reflection, stored densely.
    let mut vs: Vec<Vec<f64>> = Vec::with_capacity(p);

    for j in 0..p {
        // Compute the norm of the j-th column below the diagonal.
        let mut norm2 = 0.0;
        for i in j..m {
            let x = r[(i, j)];
            norm2 += x * x;
        }
        let norm = norm2.sqrt();
        let mut v = vec![0.0; m - j];
        if norm <= f64::EPSILON * 16.0 {
            // Degenerate column: identity reflection.
            vs.push(v);
            continue;
        }
        let alpha = if r[(j, j)] >= 0.0 { -norm } else { norm };
        for i in j..m {
            v[i - j] = r[(i, j)];
        }
        v[0] -= alpha;
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= f64::EPSILON * 16.0 {
            vs.push(vec![0.0; m - j]);
            continue;
        }
        // Apply H = I − 2vvᵀ/‖v‖² to R[j.., j..].
        for col in j..n {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * r[(i, col)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in j..m {
                r[(i, col)] -= f * v[i - j];
            }
        }
        vs.push(v);
    }

    // Zero out strictly-lower part of R and trim to p×n.
    let mut r_out = Matrix::zeros(p, n);
    for i in 0..p {
        for j in i..n {
            r_out[(i, j)] = r[(i, j)];
        }
    }

    // Accumulate Q by applying the reflections to the identity (thin: m×p).
    let mut q = Matrix::zeros(m, p);
    for i in 0..p {
        q[(i, i)] = 1.0;
    }
    for j in (0..p).rev() {
        let v = &vs[j];
        let vnorm2: f64 = v.iter().map(|x| x * x).sum();
        if vnorm2 <= f64::EPSILON * 16.0 {
            continue;
        }
        for col in 0..p {
            let mut dot = 0.0;
            for i in j..m {
                dot += v[i - j] * q[(i, col)];
            }
            let f = 2.0 * dot / vnorm2;
            for i in j..m {
                q[(i, col)] -= f * v[i - j];
            }
        }
    }

    (q, r_out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;
    use crate::rng::Rng;

    fn check_qr(m: usize, n: usize, seed: u64) {
        let mut rng = Rng::seed_from(seed);
        let a = Matrix::from_vec(m, n, rng.gaussian_vec(m * n, 1.0));
        let (q, r) = qr(&a);
        let p = m.min(n);
        assert_eq!(q.rows(), m);
        assert_eq!(q.cols(), p);
        assert_eq!(r.rows(), p);
        assert_eq!(r.cols(), n);
        // Reconstruction.
        let qr_prod = q.matmul(&r);
        assert!(rel_err(qr_prod.data(), a.data()) < 1e-10, "recon {m}x{n}");
        // Orthonormal columns: QᵀQ = I.
        let qtq = q.transpose().matmul(&q);
        let eye = Matrix::identity(p);
        assert!(rel_err(qtq.data(), eye.data()) < 1e-10, "ortho {m}x{n}");
        // R upper triangular.
        for i in 0..p {
            for j in 0..i.min(n) {
                assert!(r[(i, j)].abs() < 1e-12);
            }
        }
    }

    #[test]
    fn tall_square_wide() {
        check_qr(8, 3, 1);
        check_qr(5, 5, 2);
        check_qr(3, 7, 3);
        check_qr(40, 12, 4);
        check_qr(1, 1, 5);
    }

    #[test]
    fn rank_deficient_input() {
        // Two identical columns.
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[2.0, 2.0], &[3.0, 3.0]]);
        let (q, r) = qr(&a);
        let qr_prod = q.matmul(&r);
        assert!(rel_err(qr_prod.data(), a.data()) < 1e-10);
    }

    #[test]
    fn zero_matrix() {
        let a = Matrix::zeros(4, 2);
        let (q, r) = qr(&a);
        assert!(q.matmul(&r).fro_norm() < 1e-12);
    }
}
