//! The paper's theoretical results as executable formulas.
//!
//! These functions are used three ways: (i) by the statistical test suite
//! (`rust/tests/paper_claims.rs`) to check empirical moments against the
//! bounds of Theorem 1, (ii) by [`suggest_k`] to auto-size projections
//! from Theorem 2, and (iii) by the ablation benches that regenerate the
//! bound-vs-measurement comparison.

use crate::linalg::Matrix;

/// Theorem 1 (TT case): `Var(‖f_TT(X)‖²) ≤ (3(1+2/R)^{N−1} − 1)/k · ‖X‖⁴`.
///
/// Returns the bound normalized by `‖X‖⁴_F` (i.e. the bound for unit-norm
/// inputs).
pub fn tt_variance_bound(n: usize, r: usize, k: usize) -> f64 {
    assert!(n >= 1 && r >= 1 && k >= 1);
    let base = 1.0 + 2.0 / r as f64;
    (3.0 * base.powi(n as i32 - 1) - 1.0) / k as f64
}

/// Theorem 1 (CP case): `Var(‖f_CP(X)‖²) ≤ (3^{N−1}(1+2/R) − 1)/k · ‖X‖⁴`.
pub fn cp_variance_bound(n: usize, r: usize, k: usize) -> f64 {
    assert!(n >= 1 && r >= 1 && k >= 1);
    let base = 1.0 + 2.0 / r as f64;
    (3f64.powi(n as i32 - 1) * base - 1.0) / k as f64
}

/// Classical Gaussian RP variance: `Var(‖f(x)‖²) = 2/k · ‖x‖⁴` (the `N = 1`
/// special case both theorems reduce to).
pub fn gaussian_variance(k: usize) -> f64 {
    2.0 / k as f64
}

/// The paper's *exact* order-2 TT variance (remark after Theorem 1):
/// `Var(‖f_TT(X)‖²) = (2‖X‖⁴_F + (6/R)·Tr[(XᵀX)²]) / k`.
pub fn tt_order2_exact_variance(x: &Matrix, r: usize, k: usize) -> f64 {
    let xtx = x.t_matmul(x);
    let tr: f64 = {
        // Tr[(XᵀX)²] = ‖XᵀX‖²_F for symmetric XᵀX.
        xtx.data().iter().map(|v| v * v).sum()
    };
    let n4 = x.fro_norm().powi(4);
    (2.0 * n4 + 6.0 / r as f64 * tr) / k as f64
}

/// Theorem 2 (TT case): minimal `k` so that `f_TT(R)` embeds `m` points
/// with distortion `ε` and failure probability `δ` —
/// `k ≳ ε⁻²(1+2/R)^N log^{2N}(m/δ)` (constant taken as 1).
pub fn tt_k_lower_bound(eps: f64, n: usize, r: usize, m: usize, delta: f64) -> f64 {
    assert!(eps > 0.0 && delta > 0.0 && m >= 1);
    let log_term = (m as f64 / delta).ln().max(1.0);
    (1.0 + 2.0 / r as f64).powi(n as i32) * log_term.powi(2 * n as i32) / (eps * eps)
}

/// Theorem 2 (CP case): `k ≳ ε⁻²·3^{N−1}(1+2/R)·log^{2N}(m/δ)`.
pub fn cp_k_lower_bound(eps: f64, n: usize, r: usize, m: usize, delta: f64) -> f64 {
    assert!(eps > 0.0 && delta > 0.0 && m >= 1);
    let log_term = (m as f64 / delta).ln().max(1.0);
    3f64.powi(n as i32 - 1) * (1.0 + 2.0 / r as f64) * log_term.powi(2 * n as i32)
        / (eps * eps)
}

/// Theorem 5 concentration envelope (TT):
/// `P(|‖f(X)‖² − ‖X‖²| ≥ ε‖X‖²) ≤ C·exp(−(√k·ε)^{1/N} / ((3K)^{1/2N}·√(1+2/R)))`,
/// with the absolute constants set to `C = e²`, `K = 1`.
pub fn tt_concentration_tail(eps: f64, n: usize, r: usize, k: usize) -> f64 {
    let c = std::f64::consts::E.powi(2);
    let num = ((k as f64).sqrt() * eps).powf(1.0 / n as f64);
    let den = 3f64.powf(1.0 / (2.0 * n as f64)) * (1.0 + 2.0 / r as f64).sqrt();
    (c * (-num / den).exp()).min(1.0)
}

/// Pick the map (TT vs CP) and the smaller `k` achieving the target
/// distortion, per Theorem 2. Returns `(map_name, k)`; `k` is an `f64`
/// because the bounds overflow `usize` for high orders (that being the
/// paper's point about CP).
pub fn suggest_k(eps: f64, n: usize, r: usize, m: usize, delta: f64) -> (&'static str, f64) {
    let tt = tt_k_lower_bound(eps, n, r, m, delta);
    let cp = cp_k_lower_bound(eps, n, r, m, delta);
    if tt <= cp {
        ("tt", tt.ceil())
    } else {
        ("cp", cp.ceil())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn bounds_reduce_to_gaussian_at_order_one() {
        // N = 1, R = 1: both bounds must equal the classical 2/k.
        assert!((tt_variance_bound(1, 1, 10) - 0.2).abs() < 1e-12);
        assert!((cp_variance_bound(1, 1, 10) - 0.2).abs() < 1e-12);
        assert!((gaussian_variance(10) - 0.2).abs() < 1e-12);
    }

    #[test]
    fn rank_mitigates_tt_but_not_cp() {
        // The paper's key qualitative claim: raising R drives the TT bound
        // toward 2/k but leaves the CP bound's 3^{N-1} factor intact.
        let n = 12;
        let k = 100;
        let tt_hi = tt_variance_bound(n, 1000, k);
        let cp_hi = cp_variance_bound(n, 1000, k);
        assert!(tt_hi < 3.0 / k as f64, "tt bound with huge R ≈ 2/k, got {tt_hi}");
        assert!(
            cp_hi > 3f64.powi(10) / k as f64,
            "cp bound must keep the 3^(N-1) factor, got {cp_hi}"
        );
    }

    #[test]
    fn tt_bound_monotone_in_n_and_decreasing_in_r_and_k() {
        assert!(tt_variance_bound(5, 2, 10) > tt_variance_bound(3, 2, 10));
        assert!(tt_variance_bound(5, 5, 10) < tt_variance_bound(5, 2, 10));
        assert!(tt_variance_bound(5, 2, 100) < tt_variance_bound(5, 2, 10));
    }

    #[test]
    fn k_lower_bounds_order_tt_below_cp_at_high_order() {
        let (eps, m, delta) = (0.5, 100, 0.05);
        for n in [8usize, 12, 25] {
            let tt = tt_k_lower_bound(eps, n, 10, m, delta);
            let cp = cp_k_lower_bound(eps, n, 10, m, delta);
            assert!(tt < cp, "N={n}: tt={tt:.3e} should be < cp={cp:.3e}");
            assert_eq!(suggest_k(eps, n, 10, m, delta).0, "tt");
        }
    }

    #[test]
    fn order2_exact_variance_bounded_by_theorem1() {
        // Sub-multiplicativity: Tr[(XᵀX)²] ≤ ‖X‖⁴, so the exact variance is
        // below the Theorem-1 bound (2 + 6/R)/k·‖X‖⁴ = (3(1+2/R)−1)/k‖X‖⁴.
        let mut rng = Rng::seed_from(1);
        for r in [1usize, 5, 20] {
            let x = Matrix::from_vec(6, 7, rng.gaussian_vec(42, 1.0));
            let exact = tt_order2_exact_variance(&x, r, 10);
            let bound = tt_variance_bound(2, r, 10) * x.fro_norm().powi(4);
            assert!(exact <= bound * (1.0 + 1e-12), "R={r}: {exact} > {bound}");
        }
    }

    #[test]
    fn concentration_tail_decreases_with_k() {
        // Small k saturates at the trivial bound 1; large k must be < 1
        // and strictly smaller than the small-k value.
        let a = tt_concentration_tail(0.5, 3, 5, 10);
        let b = tt_concentration_tail(0.5, 3, 5, 1_000_000);
        assert!(b < a, "a={a} b={b}");
        assert!(b < 1.0 && b > 0.0);
        assert!(a <= 1.0);
    }
}
