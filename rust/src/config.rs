//! Application-level configuration shared by the CLI, benches and
//! examples: directory layout and common experiment knobs, parsed from
//! `util::cli::Args`.

use crate::util::cli::Args;
use std::path::PathBuf;

/// Global configuration of a `trp` invocation.
#[derive(Debug, Clone)]
pub struct AppConfig {
    /// Directory holding `manifest.json` + `*.hlo.txt`.
    pub artifacts_dir: PathBuf,
    /// Output directory for CSV results.
    pub results_dir: PathBuf,
    /// Master seed.
    pub seed: u64,
    /// Trials override for experiment sweeps (None = per-experiment default).
    pub trials: Option<usize>,
    /// Thread override.
    pub threads: Option<usize>,
    /// Quick mode (reduced sweeps).
    pub quick: bool,
}

impl AppConfig {
    /// Parse the shared options out of `args`.
    pub fn from_args(args: &Args) -> Result<Self, String> {
        Ok(Self {
            artifacts_dir: PathBuf::from(args.get_or("artifacts", "artifacts")),
            results_dir: PathBuf::from(args.get_or("out", "results")),
            seed: args.get_parsed_or("seed", 0xC0FFEEu64)?,
            trials: match args.get("trials") {
                Some(v) => Some(v.parse().map_err(|_| format!("bad --trials {v}"))?),
                None => None,
            },
            threads: match args.get("threads") {
                Some(v) => Some(v.parse().map_err(|_| format!("bad --threads {v}"))?),
                None => None,
            },
            quick: args.flag("quick"),
        })
    }

    /// Effective thread count.
    pub fn threads(&self) -> usize {
        self.threads
            .unwrap_or_else(crate::experiments::default_threads)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> AppConfig {
        let args = Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap();
        AppConfig::from_args(&args).unwrap()
    }

    #[test]
    fn defaults() {
        let c = parse("");
        assert_eq!(c.artifacts_dir, PathBuf::from("artifacts"));
        assert_eq!(c.results_dir, PathBuf::from("results"));
        assert!(!c.quick);
        assert!(c.trials.is_none());
    }

    #[test]
    fn overrides() {
        let c = parse("--artifacts /tmp/a --trials 7 --quick --seed 9");
        assert_eq!(c.artifacts_dir, PathBuf::from("/tmp/a"));
        assert_eq!(c.trials, Some(7));
        assert!(c.quick);
        assert_eq!(c.seed, 9);
    }

    #[test]
    fn bad_trials_is_an_error() {
        let args = Args::parse(["--trials".to_string(), "x".to_string()]).unwrap();
        assert!(AppConfig::from_args(&args).is_err());
    }
}
