//! Wire format: newline-delimited JSON encoding of requests/responses for
//! the TCP front-end ([`super::net`]).
//!
//! Request:
//! ```json
//! {"id": 7, "format": "tt", "dims": [3,3,3], "ranks": [1,2,2,1],
//!  "cores": [[…], […], […]]}
//! {"id": 8, "format": "cp", "dims": [3,3], "rank": 2, "factors": [[…], […]]}
//! {"id": 9, "format": "dense", "dims": [4,4], "values": [

//! …]}
//! ```
//! Response: `{"id": 7, "embedding": […], "path": "pjrt:tt_rp_medium",
//! "queued_us": 120, "exec_us": 1500}` or `{"id": 7, "error": "…"}`.

use super::request::{ProjectRequest, ProjectResponse};
use crate::linalg::Matrix;
use crate::tensor::{AnyTensor, CpTensor, DenseTensor, TtTensor};
use crate::util::json::{num_arr, obj, usize_arr, Json};

/// Encode a request as a single JSON line (no trailing newline).
pub fn encode_request(req: &ProjectRequest) -> String {
    let mut fields: Vec<(&str, Json)> = vec![("id", Json::Num(req.id as f64))];
    match &req.payload {
        AnyTensor::Dense(t) => {
            fields.push(("format", Json::Str("dense".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("values", num_arr(t.data())));
        }
        AnyTensor::Tt(t) => {
            fields.push(("format", Json::Str("tt".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("ranks", usize_arr(t.ranks())));
            fields.push((
                "cores",
                Json::Arr((0..t.order()).map(|n| num_arr(t.core(n))).collect()),
            ));
        }
        AnyTensor::Cp(t) => {
            fields.push(("format", Json::Str("cp".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("rank", Json::Num(t.rank() as f64)));
            fields.push((
                "factors",
                Json::Arr(
                    (0..t.order())
                        .map(|n| num_arr(t.factor(n).data()))
                        .collect(),
                ),
            ));
        }
    }
    obj(fields).to_string_compact()
}

/// Decode a request line.
pub fn decode_request(line: &str) -> Result<ProjectRequest, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let id = j
        .get("id")
        .and_then(Json::as_f64)
        .ok_or("missing id")? as u64;
    let format = j.get("format").and_then(Json::as_str).ok_or("missing format")?;
    let dims = j
        .get("dims")
        .and_then(Json::as_usize_vec)
        .ok_or("missing dims")?;
    let payload = match format {
        "dense" => {
            let values = num_vec(j.get("values").ok_or("missing values")?)?;
            AnyTensor::Dense(DenseTensor::from_vec(&dims, values))
        }
        "tt" => {
            let ranks = j
                .get("ranks")
                .and_then(Json::as_usize_vec)
                .ok_or("missing ranks")?;
            let cores_json = j.get("cores").and_then(Json::as_arr).ok_or("missing cores")?;
            let cores = cores_json
                .iter()
                .map(num_vec)
                .collect::<Result<Vec<_>, _>>()?;
            AnyTensor::Tt(TtTensor::from_cores(&dims, &ranks, cores))
        }
        "cp" => {
            let rank = j
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or("missing rank")?;
            let factors_json = j
                .get("factors")
                .and_then(Json::as_arr)
                .ok_or("missing factors")?;
            if factors_json.len() != dims.len() {
                return Err("factor count != mode count".into());
            }
            let factors = factors_json
                .iter()
                .zip(&dims)
                .map(|(f, &d)| Ok(Matrix::from_vec(d, rank, num_vec(f)?)))
                .collect::<Result<Vec<_>, String>>()?;
            AnyTensor::Cp(CpTensor::from_factors(factors))
        }
        other => return Err(format!("unknown format {other:?}")),
    };
    Ok(ProjectRequest::new(id, payload))
}

/// Encode a (successful or failed) response as a JSON line.
pub fn encode_response(result: &Result<ProjectResponse, String>, fallback_id: u64) -> String {
    match result {
        Ok(resp) => obj(vec![
            ("id", Json::Num(resp.id as f64)),
            ("embedding", num_arr(&resp.embedding)),
            ("path", Json::Str(resp.path.to_string())),
            ("queued_us", Json::Num(resp.queued_us as f64)),
            ("exec_us", Json::Num(resp.exec_us as f64)),
        ])
        .to_string_compact(),
        Err(e) => obj(vec![
            ("id", Json::Num(fallback_id as f64)),
            ("error", Json::Str(e.clone())),
        ])
        .to_string_compact(),
    }
}

/// Decoded response for client use.
#[derive(Debug, Clone)]
pub struct WireResponse {
    /// Request id.
    pub id: u64,
    /// Embedding when successful.
    pub embedding: Option<Vec<f64>>,
    /// Error message when failed.
    pub error: Option<String>,
    /// Serving path string.
    pub path: Option<String>,
}

/// Decode a response line.
pub fn decode_response(line: &str) -> Result<WireResponse, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let id = j.get("id").and_then(Json::as_f64).ok_or("missing id")? as u64;
    Ok(WireResponse {
        id,
        embedding: match j.get("embedding") {
            Some(v) => Some(num_vec(v)?),
            None => None,
        },
        error: j.get("error").and_then(Json::as_str).map(|s| s.to_string()),
        path: j.get("path").and_then(Json::as_str).map(|s| s.to_string()),
    })
}

fn num_vec(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or("expected array")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "expected number".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn tt_request_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let x = TtTensor::random_unit(&[3, 4, 3], 2, &mut rng);
        let req = ProjectRequest::new(42, AnyTensor::Tt(x.clone()));
        let line = encode_request(&req);
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, 42);
        match back.payload {
            AnyTensor::Tt(t) => {
                assert_eq!(t.dims(), x.dims());
                assert!((t.fro_norm() - x.fro_norm()).abs() < 1e-12);
            }
            _ => panic!("wrong format"),
        }
    }

    #[test]
    fn cp_and_dense_roundtrip() {
        let mut rng = Rng::seed_from(2);
        let cp = CpTensor::random_unit(&[3, 2, 3], 2, &mut rng);
        let back = decode_request(&encode_request(&ProjectRequest::new(
            1,
            AnyTensor::Cp(cp.clone()),
        )))
        .unwrap();
        assert!((back.payload.fro_norm() - cp.fro_norm()).abs() < 1e-12);

        let d = DenseTensor::random(&[2, 5], &mut rng);
        let back = decode_request(&encode_request(&ProjectRequest::new(
            2,
            AnyTensor::Dense(d.clone()),
        )))
        .unwrap();
        match back.payload {
            AnyTensor::Dense(t) => assert_eq!(t.data(), d.data()),
            _ => panic!("wrong format"),
        }
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let resp = ProjectResponse {
            id: 9,
            embedding: vec![0.5, -1.5],
            path: super::super::request::EnginePath::Native,
            queued_us: 10,
            exec_us: 20,
        };
        let line = encode_response(&Ok(resp), 9);
        let back = decode_response(&line).unwrap();
        assert_eq!(back.id, 9);
        assert_eq!(back.embedding.unwrap(), vec![0.5, -1.5]);
        assert_eq!(back.path.as_deref(), Some("native"));
        assert!(back.error.is_none());

        let line = encode_response(&Err("boom".into()), 7);
        let back = decode_response(&line).unwrap();
        assert_eq!(back.id, 7);
        assert_eq!(back.error.as_deref(), Some("boom"));
        assert!(back.embedding.is_none());
    }

    #[test]
    fn malformed_requests_are_rejected() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"id": 1}"#).is_err());
        assert!(decode_request(r#"{"id":1,"format":"tucker","dims":[2]}"#).is_err());
        assert!(decode_request(r#"{"id":1,"format":"dense","dims":[2]}"#).is_err());
    }
}
