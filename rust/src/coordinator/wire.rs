//! Wire format: newline-delimited JSON encoding of requests/responses for
//! the TCP front-end ([`super::net`]).
//!
//! Request (`"op"` defaults to `"project"` when omitted):
//! ```json
//! {"id": 7, "format": "tt", "dims": [3,3,3], "ranks": [1,2,2,1],
//!  "cores": [[…], […], […]]}
//! {"id": 8, "op": "insert", "format": "cp", "dims": [3,3], "rank": 2,
//!  "factors": [[…], […]]}
//! {"id": 9, "op": "query", "k": 10, "format": "dense", "dims": [4,4],
//!  "values": […]}
//! {"id": 10, "op": "delete", "target": 8, "format": "cp", "dims": [3,3]}
//! {"id": 11, "op": "stats", "format": "cp", "dims": [3,3]}
//! {"id": 12, "op": "snapshot", "format": "cp", "dims": [3,3]}
//! {"id": 13, "op": "restore", "format": "cp", "dims": [3,3]}
//! {"id": 14, "op": "metrics", "reset": false}
//! ```
//! Response: `{"id": 7, "embedding": […], "path": "native", "queued_us":
//! 120, "exec_us": 1500}`, plus `"neighbors": [{"id": 3, "dist": 0.12},
//! …]` for queries, `"removed": true|false` for deletes, `"index":
//! {"backend": "flat", "len": 12, …}` for stats, `"snapshot": {"path":
//! "…", "items": 12, "bytes": 9001}` / `"restored": 12` for persistence
//! ops — or `{"id": 7, "error": "…"}`. An error reply to a line the
//! server could not even extract an id from carries `"id": null`, so it
//! can never masquerade as a response to a legitimate request id 0.
//!
//! Any request may additionally carry `"trace": <number>` — a trace
//! context id threaded into every span the request produces and echoed
//! verbatim in the response (`"trace": <number>` rides Ok replies only
//! when the client supplied one; dispatcher-assigned span ids never
//! appear on the wire).
//!
//! Limitation: every id on the wire (`id`, `target`, neighbour ids — and
//! the `trace` context id) travels as a JSON number and therefore
//! round-trips exactly only up to 2⁵³ − 1. Wire clients must not use
//! larger ids (e.g. raw 64-bit content hashes); the in-process API has no
//! such limit.

use super::request::{Payload, ProjectRequest, ProjectResponse, RequestOp};
use crate::index::{IndexStats, Neighbor, SnapshotReport};
use crate::linalg::Matrix;
use crate::tensor::{AnyTensor, CpTensor, DenseTensor, Format, TtTensor};
use crate::util::json::{num_arr, obj, usize_arr, Json};

/// Encode a request as a single JSON line (no trailing newline).
pub fn encode_request(req: &ProjectRequest) -> String {
    let mut fields: Vec<(&str, Json)> = vec![("id", Json::Num(req.id as f64))];
    // Trace context rides every op, including the early-returning
    // `metrics` arm below.
    if let Some(t) = req.trace {
        fields.push(("trace", Json::Num(t as f64)));
    }
    match req.op {
        RequestOp::Project => {}
        RequestOp::Insert => fields.push(("op", Json::Str("insert".into()))),
        RequestOp::Query { k } => {
            fields.push(("op", Json::Str("query".into())));
            fields.push(("k", Json::Num(k as f64)));
        }
        RequestOp::Delete { target } => {
            fields.push(("op", Json::Str("delete".into())));
            fields.push(("target", Json::Num(target as f64)));
        }
        RequestOp::IndexStats => fields.push(("op", Json::Str("stats".into()))),
        RequestOp::Snapshot => fields.push(("op", Json::Str("snapshot".into()))),
        RequestOp::Restore => fields.push(("op", Json::Str("restore".into()))),
        RequestOp::Metrics { reset } => {
            // Global op: no routing signature on the wire at all.
            fields.push(("op", Json::Str("metrics".into())));
            if reset {
                fields.push(("reset", Json::Bool(true)));
            }
            return obj(fields).to_string_compact();
        }
    }
    match &req.payload {
        Payload::Tensor(AnyTensor::Dense(t)) => {
            fields.push(("format", Json::Str("dense".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("values", num_arr(t.data())));
        }
        Payload::Tensor(AnyTensor::Tt(t)) => {
            fields.push(("format", Json::Str("tt".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("ranks", usize_arr(t.ranks())));
            fields.push((
                "cores",
                Json::Arr((0..t.order()).map(|n| num_arr(t.core(n))).collect()),
            ));
        }
        Payload::Tensor(AnyTensor::Cp(t)) => {
            fields.push(("format", Json::Str("cp".into())));
            fields.push(("dims", usize_arr(t.dims())));
            fields.push(("rank", Json::Num(t.rank() as f64)));
            fields.push((
                "factors",
                Json::Arr(
                    (0..t.order())
                        .map(|n| num_arr(t.factor(n).data()))
                        .collect(),
                ),
            ));
        }
        Payload::Signature { format, dims } => {
            fields.push(("format", Json::Str(format.to_string())));
            fields.push(("dims", usize_arr(dims)));
        }
    }
    obj(fields).to_string_compact()
}

/// Decode a request line.
pub fn decode_request(line: &str) -> Result<ProjectRequest, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let id = j
        .get("id")
        .and_then(Json::as_f64)
        .ok_or("missing id")? as u64;
    let trace = j.get("trace").and_then(Json::as_f64).map(|v| v as u64);
    let op = match j.get("op").and_then(Json::as_str) {
        None | Some("project") => RequestOp::Project,
        Some("insert") => RequestOp::Insert,
        Some("query") => {
            let k = j.get("k").and_then(Json::as_usize).ok_or("query needs k")?;
            RequestOp::Query { k }
        }
        Some("delete") => {
            let target =
                j.get("target").and_then(Json::as_f64).ok_or("delete needs target")? as u64;
            RequestOp::Delete { target }
        }
        Some("stats") => RequestOp::IndexStats,
        Some("snapshot") => RequestOp::Snapshot,
        Some("restore") => RequestOp::Restore,
        Some("metrics") => {
            // Global op: needs neither format nor dims.
            let reset = j.get("reset").and_then(Json::as_bool).unwrap_or(false);
            let mut req = ProjectRequest::metrics(id, reset);
            req.trace = trace;
            return Ok(req);
        }
        Some(other) => return Err(format!("unknown op {other:?}")),
    };
    let format_str = j.get("format").and_then(Json::as_str).ok_or("missing format")?;
    let format =
        Format::parse(format_str).ok_or_else(|| format!("unknown format {format_str:?}"))?;
    let dims = j
        .get("dims")
        .and_then(Json::as_usize_vec)
        .ok_or("missing dims")?;
    // Signature-only ops carry no tensor data.
    if matches!(
        op,
        RequestOp::Delete { .. } | RequestOp::IndexStats | RequestOp::Snapshot | RequestOp::Restore
    ) {
        return Ok(ProjectRequest {
            id,
            op,
            payload: Payload::Signature { format, dims },
            trace,
        });
    }
    let tensor = match format {
        Format::Dense => {
            let values = num_vec(j.get("values").ok_or("missing values")?)?;
            AnyTensor::Dense(DenseTensor::from_vec(&dims, values))
        }
        Format::Tt => {
            let ranks = j
                .get("ranks")
                .and_then(Json::as_usize_vec)
                .ok_or("missing ranks")?;
            let cores_json = j.get("cores").and_then(Json::as_arr).ok_or("missing cores")?;
            let cores = cores_json
                .iter()
                .map(num_vec)
                .collect::<Result<Vec<_>, _>>()?;
            AnyTensor::Tt(TtTensor::from_cores(&dims, &ranks, cores))
        }
        Format::Cp => {
            let rank = j
                .get("rank")
                .and_then(Json::as_usize)
                .ok_or("missing rank")?;
            let factors_json = j
                .get("factors")
                .and_then(Json::as_arr)
                .ok_or("missing factors")?;
            if factors_json.len() != dims.len() {
                return Err("factor count != mode count".into());
            }
            let factors = factors_json
                .iter()
                .zip(&dims)
                .map(|(f, &d)| Ok(Matrix::from_vec(d, rank, num_vec(f)?)))
                .collect::<Result<Vec<_>, String>>()?;
            AnyTensor::Cp(CpTensor::from_factors(factors))
        }
    };
    Ok(ProjectRequest { id, op, payload: Payload::Tensor(tensor), trace })
}

/// Encode index statistics as a JSON object.
fn index_stats_json(s: &IndexStats) -> Json {
    obj(vec![
        ("backend", Json::Str(s.backend.clone())),
        ("len", Json::Num(s.len as f64)),
        ("dim", Json::Num(s.dim as f64)),
        ("inserts", Json::Num(s.inserts as f64)),
        ("deletes", Json::Num(s.deletes as f64)),
        ("queries", Json::Num(s.queries as f64)),
        ("buckets", Json::Num(s.buckets as f64)),
        ("max_bucket", Json::Num(s.max_bucket as f64)),
        ("shards", Json::Num(s.shards as f64)),
        ("tables", Json::Num(s.tables as f64)),
        ("bits", Json::Num(s.bits as f64)),
        ("probes", Json::Num(s.probes as f64)),
    ])
}

/// Decode index statistics from a JSON object.
fn decode_index_stats(j: &Json) -> Result<IndexStats, String> {
    let get_u64 = |key: &str| -> u64 {
        j.get(key).and_then(Json::as_f64).unwrap_or(0.0) as u64
    };
    Ok(IndexStats {
        backend: j
            .get("backend")
            .and_then(Json::as_str)
            .ok_or("index stats missing backend")?
            .to_string(),
        len: j.get("len").and_then(Json::as_usize).ok_or("index stats missing len")?,
        dim: j.get("dim").and_then(Json::as_usize).unwrap_or(0),
        inserts: get_u64("inserts"),
        deletes: get_u64("deletes"),
        queries: get_u64("queries"),
        buckets: j.get("buckets").and_then(Json::as_usize).unwrap_or(0),
        max_bucket: j.get("max_bucket").and_then(Json::as_usize).unwrap_or(0),
        // Pre-shard servers omit these; 1 shard / zero LSH shape matches
        // what they actually ran.
        shards: j.get("shards").and_then(Json::as_usize).unwrap_or(1),
        tables: j.get("tables").and_then(Json::as_usize).unwrap_or(0),
        bits: j.get("bits").and_then(Json::as_usize).unwrap_or(0),
        probes: j.get("probes").and_then(Json::as_usize).unwrap_or(0),
    })
}

/// Best-effort extraction of the `id` field from a request line that
/// failed to decode, so the error reply can echo it. Returns `None` for
/// unparseable lines or non-id values — the reply then carries `"id":
/// null`, which can never collide with a legitimate response to request
/// id 0.
pub fn parse_request_id(line: &str) -> Option<u64> {
    let v = Json::parse(line).ok()?.get("id")?.as_f64()?;
    (v.is_finite() && v >= 0.0).then_some(v as u64)
}

/// Encode a (successful or failed) response as a JSON line. `fallback_id`
/// is the id an error reply reports; `None` encodes `"id": null`
/// (unattributable failure, e.g. an unparseable request line).
pub fn encode_response(
    result: &Result<ProjectResponse, String>,
    fallback_id: Option<u64>,
) -> String {
    match result {
        Ok(resp) => {
            let mut fields: Vec<(&str, Json)> = vec![
                ("id", Json::Num(resp.id as f64)),
                ("embedding", num_arr(&resp.embedding)),
                ("path", Json::Str(resp.path.to_string())),
                ("queued_us", Json::Num(resp.queued_us as f64)),
                ("exec_us", Json::Num(resp.exec_us as f64)),
            ];
            if let Some(ns) = &resp.neighbors {
                fields.push((
                    "neighbors",
                    Json::Arr(
                        ns.iter()
                            .map(|n| {
                                obj(vec![
                                    ("id", Json::Num(n.id as f64)),
                                    ("dist", Json::Num(n.dist)),
                                ])
                            })
                            .collect(),
                    ),
                ));
            }
            if let Some(r) = resp.removed {
                fields.push(("removed", Json::Bool(r)));
            }
            if let Some(s) = &resp.index {
                fields.push(("index", index_stats_json(s)));
            }
            if let Some(sr) = &resp.snapshot {
                fields.push((
                    "snapshot",
                    obj(vec![
                        ("path", Json::Str(sr.path.clone())),
                        ("items", Json::Num(sr.items as f64)),
                        ("bytes", Json::Num(sr.bytes as f64)),
                    ]),
                ));
            }
            if let Some(n) = resp.restored {
                fields.push(("restored", Json::Num(n as f64)));
            }
            if let Some(m) = &resp.metrics {
                fields.push(("metrics", m.to_json()));
            }
            if let Some(t) = resp.trace {
                fields.push(("trace", Json::Num(t as f64)));
            }
            obj(fields).to_string_compact()
        }
        Err(e) => obj(vec![
            (
                "id",
                match fallback_id {
                    Some(id) => Json::Num(id as f64),
                    None => Json::Null,
                },
            ),
            ("error", Json::Str(e.clone())),
        ])
        .to_string_compact(),
    }
}

/// Decoded response for client use.
#[derive(Debug, Clone)]
pub struct WireResponse {
    /// Request id (`None` for error replies to unattributable requests —
    /// lines the server could not parse an id out of).
    pub id: Option<u64>,
    /// Embedding when successful.
    pub embedding: Option<Vec<f64>>,
    /// Neighbours (query responses).
    pub neighbors: Option<Vec<Neighbor>>,
    /// Delete outcome (delete responses).
    pub removed: Option<bool>,
    /// Index statistics (stats responses).
    pub index: Option<IndexStats>,
    /// Snapshot report (snapshot responses).
    pub snapshot: Option<SnapshotReport>,
    /// Items reloaded (restore responses).
    pub restored: Option<u64>,
    /// Observability snapshot (metrics responses).
    pub metrics: Option<crate::obs::ObsSnapshot>,
    /// Echo of the request's trace context id, when one was supplied.
    pub trace: Option<u64>,
    /// Error message when failed.
    pub error: Option<String>,
    /// Serving path string.
    pub path: Option<String>,
}

/// Decode a response line.
pub fn decode_response(line: &str) -> Result<WireResponse, String> {
    let j = Json::parse(line).map_err(|e| e.to_string())?;
    let id = match j.get("id") {
        None | Some(Json::Null) => None,
        Some(v) => Some(v.as_f64().ok_or("bad id")? as u64),
    };
    let neighbors = match j.get("neighbors").and_then(Json::as_arr) {
        Some(items) => Some(
            items
                .iter()
                .map(|n| -> Result<Neighbor, String> {
                    Ok(Neighbor {
                        id: n.get("id").and_then(Json::as_f64).ok_or("neighbor missing id")?
                            as u64,
                        dist: n
                            .get("dist")
                            .and_then(Json::as_f64)
                            .ok_or("neighbor missing dist")?,
                    })
                })
                .collect::<Result<Vec<_>, _>>()?,
        ),
        None => None,
    };
    Ok(WireResponse {
        id,
        embedding: match j.get("embedding") {
            Some(v) => Some(num_vec(v)?),
            None => None,
        },
        neighbors,
        removed: j.get("removed").and_then(Json::as_bool),
        index: match j.get("index") {
            Some(s) => Some(decode_index_stats(s)?),
            None => None,
        },
        snapshot: j.get("snapshot").map(|s| SnapshotReport {
            path: s
                .get("path")
                .and_then(Json::as_str)
                .unwrap_or_default()
                .to_string(),
            items: s.get("items").and_then(Json::as_f64).unwrap_or(0.0) as u64,
            bytes: s.get("bytes").and_then(Json::as_f64).unwrap_or(0.0) as u64,
        }),
        restored: j.get("restored").and_then(Json::as_f64).map(|v| v as u64),
        metrics: match j.get("metrics") {
            Some(m) => Some(crate::obs::ObsSnapshot::from_json(m)?),
            None => None,
        },
        trace: j.get("trace").and_then(Json::as_f64).map(|v| v as u64),
        error: j.get("error").and_then(Json::as_str).map(|s| s.to_string()),
        path: j.get("path").and_then(Json::as_str).map(|s| s.to_string()),
    })
}

fn num_vec(j: &Json) -> Result<Vec<f64>, String> {
    j.as_arr()
        .ok_or("expected array")?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| "expected number".to_string()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;

    #[test]
    fn tt_request_roundtrip() {
        let mut rng = Rng::seed_from(1);
        let x = TtTensor::random_unit(&[3, 4, 3], 2, &mut rng);
        let req = ProjectRequest::new(42, AnyTensor::Tt(x.clone()));
        let line = encode_request(&req);
        let back = decode_request(&line).unwrap();
        assert_eq!(back.id, 42);
        assert_eq!(back.op, RequestOp::Project);
        match back.payload {
            Payload::Tensor(AnyTensor::Tt(t)) => {
                assert_eq!(t.dims(), x.dims());
                assert!((t.fro_norm() - x.fro_norm()).abs() < 1e-12);
            }
            _ => panic!("wrong format"),
        }
    }

    #[test]
    fn cp_and_dense_roundtrip() {
        let mut rng = Rng::seed_from(2);
        let cp = CpTensor::random_unit(&[3, 2, 3], 2, &mut rng);
        let back = decode_request(&encode_request(&ProjectRequest::new(
            1,
            AnyTensor::Cp(cp.clone()),
        )))
        .unwrap();
        assert!(
            (back.payload.tensor().unwrap().fro_norm() - cp.fro_norm()).abs() < 1e-12
        );

        let d = DenseTensor::random(&[2, 5], &mut rng);
        let back = decode_request(&encode_request(&ProjectRequest::new(
            2,
            AnyTensor::Dense(d.clone()),
        )))
        .unwrap();
        match back.payload {
            Payload::Tensor(AnyTensor::Dense(t)) => assert_eq!(t.data(), d.data()),
            _ => panic!("wrong format"),
        }
    }

    #[test]
    fn index_op_request_roundtrips() {
        let mut rng = Rng::seed_from(3);
        let x = TtTensor::random_unit(&[3, 3, 3], 2, &mut rng);
        // Insert.
        let back =
            decode_request(&encode_request(&ProjectRequest::insert(7, AnyTensor::Tt(x.clone()))))
                .unwrap();
        assert_eq!(back.op, RequestOp::Insert);
        assert!(back.payload.tensor().is_some());
        // Query.
        let back =
            decode_request(&encode_request(&ProjectRequest::query(8, AnyTensor::Tt(x), 10)))
                .unwrap();
        assert_eq!(back.op, RequestOp::Query { k: 10 });
        // Delete: signature only.
        let back = decode_request(&encode_request(&ProjectRequest::delete(
            9,
            7,
            Format::Tt,
            vec![3, 3, 3],
        )))
        .unwrap();
        assert_eq!(back.op, RequestOp::Delete { target: 7 });
        assert_eq!(back.payload.format(), Format::Tt);
        assert_eq!(back.payload.dims(), &[3, 3, 3]);
        assert!(back.payload.tensor().is_none());
        // Stats: signature only.
        let back = decode_request(&encode_request(&ProjectRequest::index_stats(
            10,
            Format::Cp,
            vec![2, 2],
        )))
        .unwrap();
        assert_eq!(back.op, RequestOp::IndexStats);
        assert_eq!(back.payload.format(), Format::Cp);
        // Snapshot / restore: signature only.
        let back = decode_request(&encode_request(&ProjectRequest::snapshot(
            11,
            Format::Tt,
            vec![3, 3, 3],
        )))
        .unwrap();
        assert_eq!(back.op, RequestOp::Snapshot);
        assert!(back.payload.tensor().is_none());
        let back = decode_request(&encode_request(&ProjectRequest::restore(
            12,
            Format::Tt,
            vec![3, 3, 3],
        )))
        .unwrap();
        assert_eq!(back.op, RequestOp::Restore);
        assert_eq!(back.payload.dims(), &[3, 3, 3]);
    }

    #[test]
    fn response_roundtrip_ok_and_err() {
        let resp = ProjectResponse {
            id: 9,
            embedding: vec![0.5, -1.5],
            neighbors: None,
            removed: None,
            index: None,
            snapshot: None,
            restored: None,
            metrics: None,
            trace: None,
            path: super::super::request::EnginePath::Native,
            queued_us: 10,
            exec_us: 20,
        };
        let line = encode_response(&Ok(resp), Some(9));
        let back = decode_response(&line).unwrap();
        assert_eq!(back.id, Some(9));
        assert_eq!(back.embedding.unwrap(), vec![0.5, -1.5]);
        assert_eq!(back.path.as_deref(), Some("native"));
        assert!(back.error.is_none());
        assert!(back.neighbors.is_none());
        assert!(back.removed.is_none());
        assert!(back.index.is_none());
        assert!(back.snapshot.is_none());
        assert!(back.restored.is_none());

        let line = encode_response(&Err("boom".into()), Some(7));
        let back = decode_response(&line).unwrap();
        assert_eq!(back.id, Some(7));
        assert_eq!(back.error.as_deref(), Some("boom"));
        assert!(back.embedding.is_none());

        // Unattributable failure: id travels as JSON null, not 0.
        let line = encode_response(&Err("bad line".into()), None);
        assert!(line.contains("\"id\":null"), "got: {line}");
        let back = decode_response(&line).unwrap();
        assert_eq!(back.id, None);
        assert_eq!(back.error.as_deref(), Some("bad line"));
    }

    #[test]
    fn snapshot_and_restore_responses_roundtrip() {
        let resp = ProjectResponse {
            id: 4,
            embedding: Vec::new(),
            neighbors: None,
            removed: None,
            index: None,
            snapshot: Some(SnapshotReport {
                path: "/tmp/snaps/sig_ab.snap".into(),
                items: 12,
                bytes: 9001,
            }),
            restored: Some(12),
            metrics: None,
            trace: None,
            path: super::super::request::EnginePath::Native,
            queued_us: 1,
            exec_us: 2,
        };
        let back = decode_response(&encode_response(&Ok(resp.clone()), Some(4))).unwrap();
        assert_eq!(back.snapshot, resp.snapshot);
        assert_eq!(back.restored, Some(12));
    }

    #[test]
    fn metrics_request_and_response_roundtrip() {
        // Request: global, no signature fields on the wire.
        let line = encode_request(&ProjectRequest::metrics(14, true));
        assert!(!line.contains("format"), "got: {line}");
        let back = decode_request(&line).unwrap();
        assert_eq!(back.op, RequestOp::Metrics { reset: true });
        // A bare client line without `reset` defaults to false.
        let back = decode_request(r#"{"id":2,"op":"metrics"}"#).unwrap();
        assert_eq!(back.op, RequestOp::Metrics { reset: false });

        // Response carrying a snapshot.
        let snap = crate::obs::ObsSnapshot {
            global: super::super::metrics::Metrics::new().snapshot(),
            signatures: Vec::new(),
            gemm: Vec::new(),
            trace: crate::obs::TraceStats::default(),
            slo: Vec::new(),
        };
        let resp = ProjectResponse {
            id: 14,
            embedding: Vec::new(),
            neighbors: None,
            removed: None,
            index: None,
            snapshot: None,
            restored: None,
            metrics: Some(snap.clone()),
            trace: None,
            path: super::super::request::EnginePath::Native,
            queued_us: 0,
            exec_us: 1,
        };
        let back = decode_response(&encode_response(&Ok(resp), Some(14))).unwrap();
        assert_eq!(back.metrics.unwrap(), snap);
    }

    #[test]
    fn trace_context_roundtrips_on_requests_and_responses() {
        // Every request kind carries the trace field, including the
        // signature-free metrics op.
        let mut rng = Rng::seed_from(4);
        let x = TtTensor::random_unit(&[3, 3], 2, &mut rng);
        let req = ProjectRequest::insert(5, AnyTensor::Tt(x)).with_trace(9001);
        let line = encode_request(&req);
        assert!(line.contains("\"trace\":9001"), "got: {line}");
        assert_eq!(decode_request(&line).unwrap().trace, Some(9001));
        let line = encode_request(&ProjectRequest::metrics(6, false).with_trace(77));
        assert_eq!(decode_request(&line).unwrap().trace, Some(77));
        let line =
            encode_request(&ProjectRequest::delete(7, 5, Format::Tt, vec![3, 3]).with_trace(3));
        assert_eq!(decode_request(&line).unwrap().trace, Some(3));
        // Requests without context stay context-free on the wire.
        let line = encode_request(&ProjectRequest::metrics(8, false));
        assert!(!line.contains("trace"), "got: {line}");
        assert_eq!(decode_request(&line).unwrap().trace, None);

        // Responses echo the context only when present.
        let resp = ProjectResponse {
            id: 5,
            embedding: vec![1.0],
            neighbors: None,
            removed: None,
            index: None,
            snapshot: None,
            restored: None,
            metrics: None,
            trace: Some(9001),
            path: super::super::request::EnginePath::Native,
            queued_us: 1,
            exec_us: 2,
        };
        let line = encode_response(&Ok(resp.clone()), Some(5));
        assert!(line.contains("\"trace\":9001"), "got: {line}");
        assert_eq!(decode_response(&line).unwrap().trace, Some(9001));
        let line = encode_response(&Ok(ProjectResponse { trace: None, ..resp }), Some(5));
        assert!(!line.contains("trace"), "got: {line}");
        assert_eq!(decode_response(&line).unwrap().trace, None);
    }

    #[test]
    fn request_id_is_recovered_best_effort() {
        // Valid JSON with an id (whatever else is wrong) → recovered.
        assert_eq!(parse_request_id(r#"{"id":42,"op":"upsert"}"#), Some(42));
        // No id, negative id, non-numeric id, or non-JSON → None.
        assert_eq!(parse_request_id(r#"{"op":"insert"}"#), None);
        assert_eq!(parse_request_id(r#"{"id":-3}"#), None);
        assert_eq!(parse_request_id(r#"{"id":"seven"}"#), None);
        assert_eq!(parse_request_id("not json at all"), None);
    }

    #[test]
    fn response_with_neighbors_and_stats_roundtrips() {
        let resp = ProjectResponse {
            id: 11,
            embedding: vec![0.25],
            neighbors: Some(vec![
                Neighbor { id: 3, dist: 0.125 },
                Neighbor { id: 9, dist: 0.75 },
            ]),
            removed: Some(true),
            snapshot: None,
            restored: None,
            index: Some(IndexStats {
                backend: "lsh".into(),
                len: 12,
                dim: 16,
                inserts: 14,
                deletes: 2,
                queries: 5,
                buckets: 40,
                max_bucket: 3,
                shards: 4,
                tables: 8,
                bits: 12,
                probes: 4,
            }),
            metrics: None,
            trace: None,
            path: super::super::request::EnginePath::Native,
            queued_us: 1,
            exec_us: 2,
        };
        let line = encode_response(&Ok(resp.clone()), Some(11));
        let back = decode_response(&line).unwrap();
        assert_eq!(back.neighbors.unwrap(), resp.neighbors.unwrap());
        assert_eq!(back.removed, Some(true));
        assert_eq!(back.index.unwrap(), resp.index.unwrap());
    }

    #[test]
    fn malformed_requests_are_rejected() {
        assert!(decode_request("not json").is_err());
        assert!(decode_request(r#"{"id": 1}"#).is_err());
        assert!(decode_request(r#"{"id":1,"format":"tucker","dims":[2]}"#).is_err());
        assert!(decode_request(r#"{"id":1,"format":"dense","dims":[2]}"#).is_err());
        // Unknown op / missing op parameters.
        assert!(decode_request(r#"{"id":1,"op":"upsert","format":"tt","dims":[2]}"#).is_err());
        assert!(
            decode_request(r#"{"id":1,"op":"query","format":"dense","dims":[2],"values":[1,2]}"#)
                .is_err(),
            "query without k must be rejected"
        );
        assert!(
            decode_request(r#"{"id":1,"op":"delete","format":"tt","dims":[2]}"#).is_err(),
            "delete without target must be rejected"
        );
    }
}
