//! Service metrics: lock-free counters plus a log-bucketed latency
//! histogram (atomic, so the worker pool records without contention).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of log₂ latency buckets (1µs … ~17min).
pub const BUCKETS: usize = 30;

/// The histogram bucket a latency lands in: bucket `b` covers
/// `[2^b, 2^(b+1))` µs, clamped to the last bucket. Public so exemplar
/// storage and the SLO burn-rate engine index buckets identically to
/// [`LatencyHistogram::record`].
pub fn bucket_index(us: u64) -> usize {
    (64 - us.max(1).leading_zeros() as usize - 1).min(BUCKETS - 1)
}

/// Log₂-bucketed latency histogram over microseconds.
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    /// New empty histogram.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency observation.
    pub fn record(&self, us: u64) {
        self.buckets[bucket_index(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    /// Observation count.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in µs.
    pub fn mean_us(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    /// Approximate quantile, interpolated linearly within the containing
    /// log₂ bucket: the `r`-th of `c` observations in bucket `[2^b,
    /// 2^(b+1))` estimates as `2^b + 2^b·r/c`, so the estimate degrades
    /// gracefully from the lower edge up to the upper edge instead of
    /// always reporting the upper edge (which overstated every quantile
    /// by up to 2×).
    pub fn quantile_us(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let target = ((q * n as f64).ceil() as u64).max(1);
        let mut seen = 0;
        for (b, bucket) in self.buckets.iter().enumerate() {
            let c = bucket.load(Ordering::Relaxed);
            if c > 0 && seen + c >= target {
                let lower = 1u64 << b;
                let rank = target - seen; // 1..=c
                return lower + lower.saturating_mul(rank) / c;
            }
            seen += c;
        }
        1u64 << BUCKETS
    }

    /// Raw bucket counts (bucket `b` covers `[2^b, 2^(b+1))` µs); the
    /// exported histogram representation.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// All service metrics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Requests accepted into the queue.
    pub submitted: AtomicU64,
    /// Requests completed successfully.
    pub completed: AtomicU64,
    /// Requests that failed (engine error).
    pub failed: AtomicU64,
    /// PJRT batches executed.
    pub pjrt_batches: AtomicU64,
    /// Native batches executed (one `project_batch_into` call each).
    pub native_batches: AtomicU64,
    /// Requests served by the native path.
    pub native_requests: AtomicU64,
    /// Requests served by the PJRT path.
    pub pjrt_requests: AtomicU64,
    /// Padding slots wasted across all PJRT batches.
    pub padded_slots: AtomicU64,
    /// High-water adaptive native flush-size target across all lanes
    /// since startup (equals the configured `native_max_batch` when
    /// adaptation is off).
    pub native_flush_max: AtomicU64,
    /// Index items inserted through the coordinator.
    pub index_inserts: AtomicU64,
    /// Index deletes processed through the coordinator.
    pub index_deletes: AtomicU64,
    /// Index queries answered through the coordinator.
    pub index_queries: AtomicU64,
    /// Index snapshots written (explicit `snapshot` ops + periodic).
    pub index_snapshots: AtomicU64,
    /// Index restores applied (`restore` wire ops; startup `--restore`
    /// happens before the metrics are observable and is not counted).
    pub index_restores: AtomicU64,
    /// High-water partition imbalance across all signatures: `max − min`
    /// of any signature's per-shard live item counts, sampled after each
    /// index flush (0 while unsharded or perfectly balanced) — makes a
    /// skewed id hash observable instead of silently serializing one
    /// lane. Resettable ([`Metrics::reset_high_water`]) so one early
    /// skewed flush does not poison the gauge forever; the matching
    /// current value is `index_shard_skew_now`.
    pub index_shard_max_skew: AtomicU64,
    /// High-water count of one signature's shard passes executing
    /// concurrently — >1 proves a single hot signature's index phases
    /// actually spread across workers. Resettable like
    /// `index_shard_max_skew`.
    pub index_shard_parallel: AtomicU64,
    /// Last-sampled (current) partition imbalance, refreshed after every
    /// index flush — decays when balance recovers, unlike the high-water.
    pub index_shard_skew_now: AtomicU64,
    /// Last-sampled count of concurrently executing shard passes.
    pub index_shard_parallel_now: AtomicU64,
    /// WAL records appended (inserts + deletes logged; 0 with WAL off).
    pub wal_appends: AtomicU64,
    /// WAL group-commit fsyncs issued (one per touched lane per flush
    /// under the `flush` policy — the batching is what this counts).
    pub wal_fsyncs: AtomicU64,
    /// WAL records replayed by startup crash recovery.
    pub wal_replayed: AtomicU64,
    /// End-to-end latency (submit → response), recorded for successful
    /// *and* failed replies so error tail latency is visible.
    pub e2e_latency: LatencyHistogram,
}

/// A point-in-time copy of the metrics for reporting.
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    /// See [`Metrics::submitted`].
    pub submitted: u64,
    /// See [`Metrics::completed`].
    pub completed: u64,
    /// See [`Metrics::failed`].
    pub failed: u64,
    /// See [`Metrics::pjrt_batches`].
    pub pjrt_batches: u64,
    /// See [`Metrics::native_batches`].
    pub native_batches: u64,
    /// See [`Metrics::native_requests`].
    pub native_requests: u64,
    /// See [`Metrics::pjrt_requests`].
    pub pjrt_requests: u64,
    /// See [`Metrics::padded_slots`].
    pub padded_slots: u64,
    /// See [`Metrics::native_flush_max`].
    pub native_flush_max: u64,
    /// See [`Metrics::index_inserts`].
    pub index_inserts: u64,
    /// See [`Metrics::index_deletes`].
    pub index_deletes: u64,
    /// See [`Metrics::index_queries`].
    pub index_queries: u64,
    /// See [`Metrics::index_snapshots`].
    pub index_snapshots: u64,
    /// See [`Metrics::index_restores`].
    pub index_restores: u64,
    /// See [`Metrics::index_shard_max_skew`].
    pub index_shard_max_skew: u64,
    /// See [`Metrics::index_shard_parallel`].
    pub index_shard_parallel: u64,
    /// See [`Metrics::index_shard_skew_now`].
    pub index_shard_skew_now: u64,
    /// See [`Metrics::index_shard_parallel_now`].
    pub index_shard_parallel_now: u64,
    /// See [`Metrics::wal_appends`].
    pub wal_appends: u64,
    /// See [`Metrics::wal_fsyncs`].
    pub wal_fsyncs: u64,
    /// See [`Metrics::wal_replayed`].
    pub wal_replayed: u64,
    /// Mean end-to-end latency (µs).
    pub mean_latency_us: f64,
    /// p50 end-to-end latency (µs, interpolated within its bucket).
    pub p50_latency_us: u64,
    /// p99 end-to-end latency (µs, interpolated within its bucket).
    pub p99_latency_us: u64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a snapshot.
    pub fn snapshot(&self) -> MetricsSnapshot {
        MetricsSnapshot {
            submitted: self.submitted.load(Ordering::Relaxed),
            completed: self.completed.load(Ordering::Relaxed),
            failed: self.failed.load(Ordering::Relaxed),
            pjrt_batches: self.pjrt_batches.load(Ordering::Relaxed),
            native_batches: self.native_batches.load(Ordering::Relaxed),
            native_requests: self.native_requests.load(Ordering::Relaxed),
            pjrt_requests: self.pjrt_requests.load(Ordering::Relaxed),
            padded_slots: self.padded_slots.load(Ordering::Relaxed),
            native_flush_max: self.native_flush_max.load(Ordering::Relaxed),
            index_inserts: self.index_inserts.load(Ordering::Relaxed),
            index_deletes: self.index_deletes.load(Ordering::Relaxed),
            index_queries: self.index_queries.load(Ordering::Relaxed),
            index_snapshots: self.index_snapshots.load(Ordering::Relaxed),
            index_restores: self.index_restores.load(Ordering::Relaxed),
            index_shard_max_skew: self.index_shard_max_skew.load(Ordering::Relaxed),
            index_shard_parallel: self.index_shard_parallel.load(Ordering::Relaxed),
            index_shard_skew_now: self.index_shard_skew_now.load(Ordering::Relaxed),
            index_shard_parallel_now: self.index_shard_parallel_now.load(Ordering::Relaxed),
            wal_appends: self.wal_appends.load(Ordering::Relaxed),
            wal_fsyncs: self.wal_fsyncs.load(Ordering::Relaxed),
            wal_replayed: self.wal_replayed.load(Ordering::Relaxed),
            mean_latency_us: self.e2e_latency.mean_us(),
            p50_latency_us: self.e2e_latency.quantile_us(0.50),
            p99_latency_us: self.e2e_latency.quantile_us(0.99),
        }
    }

    /// Zero the resettable high-water gauges (`index_shard_max_skew`,
    /// `index_shard_parallel`) so a fresh observation window starts —
    /// the `metrics` wire op with `reset:true` calls this *after*
    /// snapshotting, so the reply still reports the pre-reset values.
    pub fn reset_high_water(&self) {
        self.index_shard_max_skew.store(0, Ordering::Relaxed);
        self.index_shard_parallel.store(0, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_counts() {
        let h = LatencyHistogram::new();
        for us in [1u64, 10, 100, 1_000, 10_000] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        assert!((h.mean_us() - 11_111.0 / 5.0).abs() < 1.0);
    }

    #[test]
    fn quantiles_are_monotone_and_bracket() {
        let h = LatencyHistogram::new();
        for i in 0..1000u64 {
            h.record(i + 1);
        }
        let p50 = h.quantile_us(0.5);
        let p99 = h.quantile_us(0.99);
        assert!(p50 <= p99);
        // p50 of 1..=1000 is ~500. The containing bucket is [256, 512);
        // interpolation lands near the true value instead of the upper
        // edge (512 before, a 2% overstatement; up to 2× in general).
        assert!((490..=512).contains(&p50), "p50={p50}");
        // p99 is 990; its bucket [512, 1024) only spans up to 1000, so
        // the uniform-within-bucket estimate overshoots slightly but
        // stays inside the bucket.
        assert!((990..=1024).contains(&p99), "p99={p99}");
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // 4 observations, all in bucket [256, 512): ranks 1..=4 must
        // spread across the bucket, not collapse onto the upper edge.
        let h = LatencyHistogram::new();
        for _ in 0..4 {
            h.record(300);
        }
        assert_eq!(h.quantile_us(0.25), 256 + 64);
        assert_eq!(h.quantile_us(0.50), 256 + 128);
        assert_eq!(h.quantile_us(1.00), 512);
        // A single observation reports the bucket's upper edge.
        let one = LatencyHistogram::new();
        one.record(3);
        assert_eq!(one.quantile_us(0.5), 4);
    }

    #[test]
    fn bucket_counts_expose_the_distribution() {
        let h = LatencyHistogram::new();
        h.record(1); // bucket 0
        h.record(5); // bucket 2
        h.record(5); // bucket 2
        let buckets = h.bucket_counts();
        assert_eq!(buckets.len(), 30);
        assert_eq!(buckets[0], 1);
        assert_eq!(buckets[2], 2);
        assert_eq!(buckets.iter().sum::<u64>(), h.count());
    }

    #[test]
    fn empty_histogram_is_zero() {
        let h = LatencyHistogram::new();
        assert_eq!(h.quantile_us(0.99), 0);
        assert_eq!(h.mean_us(), 0.0);
    }

    #[test]
    fn snapshot_reflects_counters() {
        let m = Metrics::new();
        m.submitted.fetch_add(3, Ordering::Relaxed);
        m.completed.fetch_add(2, Ordering::Relaxed);
        m.e2e_latency.record(100);
        let s = m.snapshot();
        assert_eq!(s.submitted, 3);
        assert_eq!(s.completed, 2);
        assert!(s.mean_latency_us > 0.0);
    }

    #[test]
    fn high_water_gauges_reset_but_counters_survive() {
        let m = Metrics::new();
        m.index_shard_max_skew.fetch_max(9, Ordering::Relaxed);
        m.index_shard_parallel.fetch_max(4, Ordering::Relaxed);
        m.index_shard_skew_now.store(2, Ordering::Relaxed);
        m.index_inserts.fetch_add(7, Ordering::Relaxed);
        m.reset_high_water();
        let s = m.snapshot();
        assert_eq!(s.index_shard_max_skew, 0);
        assert_eq!(s.index_shard_parallel, 0);
        assert_eq!(s.index_shard_skew_now, 2, "current gauge untouched");
        assert_eq!(s.index_inserts, 7, "counters untouched");
    }

    #[test]
    fn huge_latency_clamps_to_last_bucket() {
        let h = LatencyHistogram::new();
        h.record(u64::MAX);
        assert_eq!(h.count(), 1);
        assert!(h.quantile_us(1.0) >= 1 << 29);
    }

    #[test]
    fn bucket_index_matches_record() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
        for us in [1u64, 7, 300, 1_000_000] {
            let h = LatencyHistogram::new();
            h.record(us);
            assert_eq!(h.bucket_counts()[bucket_index(us)], 1);
        }
    }
}
