//! L3 coordinator: the serving layer that makes the projection maps
//! consumable as a compression service.
//!
//! Architecture (vLLM-router mold, scaled to this paper's workload):
//!
//! ```text
//!  submit() ──▶ bounded queue ──▶ dispatcher thread
//!                                   │ route on (format, dims, rank)
//!                  ┌────────────────┴───────────────┐
//!                  ▼                                ▼
//!          native path (per-map             PJRT path (per-artifact
//!          dynamic batcher: size B          dynamic batcher: size B
//!          or deadline → worker pool,       or deadline, zero-padded)
//!          one project_batch_into per               ▼
//!          flush, pooled workspaces)        runtime::PjrtEngine
//!                  ▼
//!          projections::* batched kernels
//!                  └────────────▶ responses ◀───────┘
//! ```
//!
//! Invariants (property-tested in `rust/tests/coordinator_props.rs` and
//! `rust/tests/index_props.rs`): every submitted request gets exactly one
//! response; responses carry the request's id; batch padding never leaks
//! between requests; the registry returns the identical map for identical
//! keys (seed determinism); bounded queues provide backpressure instead
//! of unbounded growth.
//!
//! Beyond pure projection, the coordinator serves the similarity-search
//! subsystem ([`crate::index`]) through four extra wire ops — `insert`,
//! `query`, `delete`, `stats` — routed per map signature: each signature
//! owns one deterministic projection map *and* one ANN index over the
//! embeddings that map produced ([`IndexRegistry`]).

mod batcher;
mod metrics;
pub mod net;
mod request;
mod router;
mod server;
mod state;
pub mod wire;

pub use batcher::{ArrivalRate, Batcher, BatcherConfig};
pub use metrics::{bucket_index, LatencyHistogram, Metrics, MetricsSnapshot, BUCKETS};
pub use net::{NetClient, NetServer};
pub use request::{EnginePath, Payload, ProjectRequest, ProjectResponse, RequestOp};
pub use router::{RouteKey, RouteTarget, Router};
pub use server::{Coordinator, CoordinatorConfig, Reply};
pub use state::{
    snapshot_file_stem, IndexRegistry, IndexSlot, MapKey, MapKind, ProjectionRegistry,
    RestorePlan, SharedIndex, WorkspacePool,
};
