//! Projection-map state: the registry of drawn maps.
//!
//! A serving deployment must answer every request for the same signature
//! with the *same* random map — otherwise embeddings are not comparable
//! across requests. The registry derives each map's seed deterministically
//! from `(master_seed, map key)`, so a restarted coordinator reproduces
//! identical maps, and the PJRT and native paths share one draw.

use crate::index::persist::Cursor;
use crate::index::{
    build_index, AnnIndex, BackendKind, IndexSnapshot, LshConfig, SnapshotReport,
};
use crate::projections::{
    CpProjection, GaussianProjection, Projection, SparseKind, SparseProjection, TtProjection,
    Workspace,
};
use crate::rng::Rng;
use crate::runtime::{pack, ArtifactKind, ArtifactSpec};
use anyhow::Result;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Which projection family a registry entry uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MapKind {
    /// `f_TT(R)`.
    Tt {
        /// TT rank R.
        rank: usize,
    },
    /// `f_CP(R)`.
    Cp {
        /// CP rank R.
        rank: usize,
    },
    /// Dense Gaussian RP.
    Gaussian,
    /// Very sparse RP (Li et al.).
    VerySparse,
}

/// Registry key: one map per (kind, input dims, k).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MapKey {
    /// Map family + rank.
    pub kind: MapKind,
    /// Input mode sizes.
    pub dims: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
}

impl MapKey {
    /// Canonical byte encoding, embedded in index snapshot headers so a
    /// restored file routes back to its signature:
    /// `kind tag u8 | rank u64 | ndims u32 | dims u64… | k u64` (LE).
    pub fn encode(&self) -> Vec<u8> {
        let (tag, rank): (u8, u64) = match self.kind {
            MapKind::Tt { rank } => (1, rank as u64),
            MapKind::Cp { rank } => (2, rank as u64),
            MapKind::Gaussian => (3, 0),
            MapKind::VerySparse => (4, 0),
        };
        let mut out = Vec::with_capacity(1 + 8 + 4 + self.dims.len() * 8 + 8);
        out.push(tag);
        out.extend_from_slice(&rank.to_le_bytes());
        out.extend_from_slice(&(self.dims.len() as u32).to_le_bytes());
        for &d in &self.dims {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&(self.k as u64).to_le_bytes());
        out
    }

    /// Inverse of [`MapKey::encode`] (reads through the persistence
    /// layer's bounds-checked [`Cursor`]).
    pub fn decode(bytes: &[u8]) -> std::result::Result<MapKey, String> {
        let mut cur = Cursor::new(bytes);
        let tag = cur.u8()?;
        let rank = cur.u64()? as usize;
        let ndims = cur.u32()? as usize;
        // Validate the advertised length before allocating for it (this
        // also rejects trailing bytes).
        if bytes.len() != 13 + ndims * 8 + 8 {
            return Err("map key length mismatch".into());
        }
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(cur.u64()? as usize);
        }
        let k = cur.u64()? as usize;
        let kind = match tag {
            1 => MapKind::Tt { rank },
            2 => MapKind::Cp { rank },
            3 => MapKind::Gaussian,
            4 => MapKind::VerySparse,
            other => return Err(format!("unknown map kind tag {other}")),
        };
        Ok(MapKey { kind, dims, k })
    }
}

/// Cached PJRT parameter buffers for one map (packed once, reused for
/// every batch).
#[derive(Debug, Clone)]
pub enum PackedParams {
    /// `(g_first, g_mid, g_last)` for TT artifacts.
    Tt(Arc<(Vec<f32>, Vec<f32>, Vec<f32>)>),
    /// `a` for CP artifacts.
    Cp(Arc<Vec<f32>>),
    /// `w` for dense artifacts.
    Dense(Arc<Vec<f32>>),
}

/// A registry entry: the map plus optional packed parameters.
pub struct MapEntry {
    /// The projection map (native execution).
    pub map: Arc<dyn Projection>,
    /// Packed PJRT parameters, present when an artifact matches this map.
    pub packed: Option<PackedParams>,
}

/// Pool of reusable projection [`Workspace`]s for the worker threads.
///
/// A worker acquires a workspace for the duration of one batch and
/// releases it afterwards; each workspace's buffers warm up to the
/// high-water batch size, so steady-state native batches perform no
/// allocation inside the projection kernels. The pool never shrinks —
/// its population is bounded by the worker count (a worker holds at most
/// one workspace at a time).
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    /// Recyclable `f64` buffers: flushed-batch `out` buffers and the
    /// index path's query-staging buffers (the buffers that come back;
    /// per-reply embeddings leave the process inside responses and are
    /// deliberately not pooled).
    bufs: Mutex<Vec<Vec<f64>>>,
}

/// Cap on pooled `f64` buffers: the pool only has to cover the in-flight
/// flushes of the worker pool.
const MAX_POOLED_BUFS: usize = 64;

impl WorkspacePool {
    /// New empty pool (workspaces are created lazily on first acquire).
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a warm workspace, or a fresh one when the pool is empty.
    pub fn acquire(&self) -> Workspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a workspace for reuse.
    pub fn release(&self, ws: Workspace) {
        self.free.lock().unwrap().push(ws);
    }

    /// Number of idle pooled workspaces.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    /// Take a zeroed `len`-element buffer, reusing a pooled allocation
    /// when one of a fitting size exists (steady-state flushes allocate
    /// nothing). "Fitting" bounds the over-capacity: a flush-sized buffer
    /// must not be handed out as a `k`-sized reply embedding, or its full
    /// capacity leaves the process inside the response.
    pub fn acquire_buf(&self, len: usize) -> Vec<f64> {
        let mut bufs = self.bufs.lock().unwrap();
        let fit = bufs
            .iter()
            .position(|b| b.capacity() >= len && b.capacity() <= len.saturating_mul(4).max(64));
        let mut buf = match fit {
            Some(i) => bufs.swap_remove(i),
            None => Vec::new(),
        };
        drop(bufs);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer for reuse. Buffers handed to clients inside a
    /// response never come back — only flush `out` buffers and embeddings
    /// whose reply channel was dropped are recycled — so the pool is
    /// bounded by [`MAX_POOLED_BUFS`] and excess buffers are simply freed.
    pub fn release_buf(&self, buf: Vec<f64>) {
        let mut bufs = self.bufs.lock().unwrap();
        if bufs.len() < MAX_POOLED_BUFS {
            bufs.push(buf);
        }
    }

    /// Number of idle pooled buffers.
    pub fn idle_bufs(&self) -> usize {
        self.bufs.lock().unwrap().len()
    }
}

/// Stable seed for a map key: FNV-1a over the key's canonical encoding,
/// mixed with `master_seed`. Shared by the projection and index
/// registries (the index registry perturbs the master so hash hyperplanes
/// never reuse a projection map's stream).
fn map_key_seed(master_seed: u64, key: &MapKey) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ master_seed;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    };
    match key.kind {
        MapKind::Tt { rank } => {
            eat(1);
            eat(rank as u64);
        }
        MapKind::Cp { rank } => {
            eat(2);
            eat(rank as u64);
        }
        MapKind::Gaussian => eat(3),
        MapKind::VerySparse => eat(4),
    }
    for &d in &key.dims {
        eat(d as u64);
    }
    eat(key.k as u64);
    h
}

/// Deterministic, thread-safe projection-map registry.
pub struct ProjectionRegistry {
    master_seed: u64,
    maps: Mutex<HashMap<MapKey, Arc<MapEntry>>>,
}

impl ProjectionRegistry {
    /// New registry; all map draws derive from `master_seed`.
    pub fn new(master_seed: u64) -> Self {
        Self { master_seed, maps: Mutex::new(HashMap::new()) }
    }

    /// Stable per-key seed: hash the key fields into the master seed.
    fn seed_for(&self, key: &MapKey) -> u64 {
        map_key_seed(self.master_seed, key)
    }

    /// Get or create the map for `key` (no PJRT packing).
    pub fn get_or_create(&self, key: &MapKey) -> Arc<MapEntry> {
        self.get_or_create_inner(key, None).expect("native map creation cannot fail")
    }

    /// Get or create the map for `key`, packing parameters for `spec`'s
    /// artifact layout on first creation.
    pub fn get_or_create_for_artifact(
        &self,
        key: &MapKey,
        spec: &ArtifactSpec,
    ) -> Result<Arc<MapEntry>> {
        self.get_or_create_inner(key, Some(spec))
    }

    fn get_or_create_inner(
        &self,
        key: &MapKey,
        spec: Option<&ArtifactSpec>,
    ) -> Result<Arc<MapEntry>> {
        let mut maps = self.maps.lock().unwrap();
        if let Some(e) = maps.get(key) {
            // Upgrade an existing entry with packing if newly needed.
            if e.packed.is_some() || spec.is_none() {
                return Ok(Arc::clone(e));
            }
        }
        let mut rng = Rng::seed_from(self.seed_for(key));
        let (map, packed): (Arc<dyn Projection>, Option<PackedParams>) = match key.kind {
            MapKind::Tt { rank } => {
                let f = TtProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Tt => {
                        let (n, d, r, _) = s.tt_meta()?;
                        Some(PackedParams::Tt(Arc::new(pack::pack_tt_projection(
                            &f, n, d, r,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Cp { rank } => {
                let f = CpProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Cp => {
                        let n = s.n_modes.unwrap();
                        let d = s.dim.unwrap();
                        Some(PackedParams::Cp(Arc::new(pack::pack_cp_projection(
                            &f, n, d, rank,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Gaussian => {
                let f = GaussianProjection::new(&key.dims, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Dense => {
                        Some(PackedParams::Dense(Arc::new(pack::pack_dense_projection(&f))))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::VerySparse => {
                let f = SparseProjection::new(&key.dims, key.k, SparseKind::VerySparse, &mut rng);
                (Arc::new(f), None)
            }
        };
        let entry = Arc::new(MapEntry { map, packed });
        maps.insert(key.clone(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Number of registered maps.
    pub fn len(&self) -> usize {
        self.maps.lock().unwrap().len()
    }

    /// True when no maps have been drawn yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One signature's ANN index plus the FIFO sequencer that orders the
/// index phases of its flushes.
///
/// Flushes for one signature are dispatched in arrival order but execute
/// on different pool workers, so without sequencing a pipelined
/// `insert → delete` pair could reach the index reversed. The dispatcher
/// reserves a ticket per index-carrying flush ([`IndexSlot::issue_ticket`],
/// called in dispatch order from the single dispatcher thread); the worker
/// runs its index phase inside [`IndexSlot::run_in_turn`], which blocks
/// until every earlier ticket has completed. The worker pool dequeues
/// jobs FIFO, so ticket `n` always starts before `n+1` and the wait can
/// never deadlock.
pub struct IndexSlot {
    /// The signature this index serves (snapshot files are keyed on it).
    pub key: MapKey,
    /// The ANN index. Lock it directly for out-of-band access; the
    /// coordinator's flushes go through [`IndexSlot::run_in_turn`].
    pub index: Mutex<Box<dyn AnnIndex>>,
    /// Next ticket allowed to run its index phase.
    turn: Mutex<u64>,
    turn_done: Condvar,
    /// Tickets handed out so far.
    issued: AtomicU64,
    /// Mutations (inserts + effective deletes) since the last snapshot —
    /// drives the `snapshot_every_ops` periodic-snapshot trigger.
    mutations: AtomicU64,
}

impl IndexSlot {
    fn new(key: MapKey, index: Box<dyn AnnIndex>) -> Self {
        Self {
            key,
            index: Mutex::new(index),
            turn: Mutex::new(0),
            turn_done: Condvar::new(),
            issued: AtomicU64::new(0),
            mutations: AtomicU64::new(0),
        }
    }

    /// Record `n` mutations; returns the running total since the last
    /// snapshot.
    pub fn note_mutations(&self, n: u64) -> u64 {
        self.mutations.fetch_add(n, Ordering::Relaxed) + n
    }

    /// Reset the mutation counter (after a successful snapshot/restore).
    pub fn reset_mutations(&self) {
        self.mutations.store(0, Ordering::Relaxed);
    }

    /// Reserve the next position in this signature's index order. Call in
    /// dispatch order (the coordinator calls it from the dispatcher
    /// thread, before submitting the flush to the worker pool).
    pub fn issue_ticket(&self) -> u64 {
        self.issued.fetch_add(1, Ordering::Relaxed)
    }

    /// Block until `ticket` is at the head of the order, run `f` on the
    /// locked index, then release the turn to the next ticket. The
    /// closure receives the owning `Box` so a `restore` op can swap the
    /// whole index while the turn is held.
    pub fn run_in_turn<R>(&self, ticket: u64, f: impl FnOnce(&mut Box<dyn AnnIndex>) -> R) -> R {
        let mut turn = self.turn.lock().unwrap();
        while *turn != ticket {
            turn = self.turn_done.wait(turn).unwrap();
        }
        let result = {
            let mut index = self.index.lock().unwrap();
            f(&mut index)
        };
        *turn += 1;
        self.turn_done.notify_all();
        result
    }
}

/// A per-signature index shared between the registry and worker jobs.
pub type SharedIndex = Arc<IndexSlot>;

/// Deterministic, thread-safe registry of per-signature ANN indexes.
///
/// One index per [`MapKey`]: every item stored in an index was embedded by
/// that key's projection map, so distances are comparable. Indexes are
/// created lazily on the first index op for a signature; the LSH backend's
/// hyperplanes are seeded from `(master_seed, key)` so a restarted
/// coordinator reproduces identical bucket assignments.
pub struct IndexRegistry {
    master_seed: u64,
    backend: BackendKind,
    lsh: LshConfig,
    /// Directory index snapshots are written to / reloaded from (`None`
    /// disables the `snapshot`/`restore` wire ops and periodic
    /// snapshots).
    snapshot_dir: Option<PathBuf>,
    /// Rotation depth: how many snapshot files to keep per signature
    /// (oldest pruned after each successful write; minimum 1).
    snapshot_keep: usize,
    indexes: Mutex<HashMap<MapKey, SharedIndex>>,
}

/// Default rotation depth: the latest snapshot plus one predecessor, so a
/// snapshot that lands torn or wrong still leaves a recovery point.
pub const DEFAULT_SNAPSHOT_KEEP: usize = 2;

/// Snapshot file-name prefix of a signature: a salted key hash, stable
/// across master seeds and processes so `--restore` finds files by
/// content. Full names are `<prefix>.<seq>.snap` with a monotonically
/// increasing per-signature sequence number (rotation), and the legacy
/// unsequenced `<prefix>.snap` reads as sequence 0.
fn snapshot_prefix(key: &MapKey) -> String {
    format!("sig_{:016x}", map_key_seed(0x5EED_F11E, key))
}

/// Split a snapshot file name into `(signature stem, sequence)`.
/// `sig_ab.00000003.snap → ("sig_ab", 3)`, legacy `sig_ab.snap →
/// ("sig_ab", 0)`; `None` for non-snapshot names.
fn parse_snap_name(name: &str) -> Option<(String, u64)> {
    let rest = name.strip_suffix(".snap")?;
    if let Some((stem, seq)) = rest.rsplit_once('.') {
        if let Ok(s) = seq.parse::<u64>() {
            return Some((stem.to_string(), s));
        }
    }
    Some((rest.to_string(), 0))
}

/// All snapshot files of one signature in `dir`, ascending by sequence.
/// IO errors propagate: treating an unreadable directory as "no
/// snapshots" would restart the rotation sequence below existing files
/// (so a later restore would silently load a stale higher sequence).
fn list_snapshots(dir: &Path, prefix: &str) -> std::result::Result<Vec<(u64, PathBuf)>, String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    let mut found: Vec<(u64, PathBuf)> = Vec::new();
    for entry in rd {
        let p = entry.map_err(|e| format!("read {}: {e}", dir.display()))?.path();
        let name = match p.file_name().and_then(|s| s.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if let Some((stem, seq)) = parse_snap_name(&name) {
            if stem == prefix {
                found.push((seq, p));
            }
        }
    }
    found.sort();
    Ok(found)
}

impl IndexRegistry {
    /// New registry creating `backend` indexes (LSH shape from `lsh`).
    pub fn new(master_seed: u64, backend: BackendKind, lsh: LshConfig) -> Self {
        Self {
            master_seed,
            backend,
            lsh,
            snapshot_dir: None,
            snapshot_keep: DEFAULT_SNAPSHOT_KEEP,
            indexes: Mutex::new(HashMap::new()),
        }
    }

    /// Set the snapshot directory (builder-style).
    pub fn with_snapshot_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.snapshot_dir = dir;
        self
    }

    /// Set the per-signature rotation depth (builder-style; clamped to
    /// ≥ 1 — "keep zero snapshots" would delete the file just written).
    pub fn with_snapshot_keep(mut self, keep: usize) -> Self {
        self.snapshot_keep = keep.max(1);
        self
    }

    /// The configured snapshot directory, when any.
    pub fn snapshot_dir(&self) -> Option<&Path> {
        self.snapshot_dir.as_deref()
    }

    /// Get or lazily create the index slot for `key` (dimension `key.k`).
    pub fn get_or_create(&self, key: &MapKey) -> SharedIndex {
        let mut indexes = self.indexes.lock().unwrap();
        if let Some(slot) = indexes.get(key) {
            return Arc::clone(slot);
        }
        // Perturb the master so the hyperplane stream differs from the
        // projection map drawn for the same key.
        let seed = map_key_seed(self.master_seed ^ 0xA11_1DE8_5EED, key);
        let slot = Arc::new(IndexSlot::new(
            key.clone(),
            build_index(self.backend, key.k, &self.lsh, seed),
        ));
        indexes.insert(key.clone(), Arc::clone(&slot));
        slot
    }

    /// Write a snapshot of `index` (the live contents of `slot`) to the
    /// configured directory under the signature's next sequence number,
    /// then prune the oldest files beyond the rotation depth (only after
    /// the atomic rename succeeded — a failed write never costs an
    /// existing recovery point). The caller must hold the slot's
    /// sequencer turn (or otherwise own the index) so the capture is a
    /// consistent cut between index ops.
    pub fn snapshot_slot(
        &self,
        slot: &IndexSlot,
        index: &dyn AnnIndex,
    ) -> std::result::Result<SnapshotReport, String> {
        let dir = self.snapshot_dir.as_ref().ok_or("no snapshot_dir configured")?;
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let snap = IndexSnapshot::capture(slot.key.encode(), index);
        let prefix = snapshot_prefix(&slot.key);
        let mut existing = list_snapshots(dir, &prefix)?;
        let seq = existing.last().map(|(s, _)| s + 1).unwrap_or(1);
        let path = dir.join(format!("{prefix}.{seq:08}.snap"));
        let items = snap.items.len() as u64;
        let bytes = snap.write_atomic(&path)?;
        existing.push((seq, path.clone()));
        while existing.len() > self.snapshot_keep {
            // Best-effort prune: a leftover old file is re-pruned next
            // time and never shadows the newest sequence on restore.
            let (_, old) = existing.remove(0);
            let _ = std::fs::remove_file(old);
        }
        Ok(SnapshotReport { path: path.display().to_string(), items, bytes })
    }

    /// Reload `slot`'s index from its newest snapshot file in the
    /// configured directory, replacing the live contents. Caller must
    /// hold the slot's sequencer turn. Returns the restored item count.
    pub fn restore_slot(
        &self,
        slot: &IndexSlot,
        index: &mut Box<dyn AnnIndex>,
    ) -> std::result::Result<u64, String> {
        let dir = self.snapshot_dir.as_ref().ok_or("no snapshot_dir configured")?;
        let prefix = snapshot_prefix(&slot.key);
        let snaps = list_snapshots(dir, &prefix)?;
        let (_, path) = snaps
            .last()
            .ok_or_else(|| format!("no snapshot for this signature in {}", dir.display()))?;
        let snap = IndexSnapshot::read(path)?;
        let key = MapKey::decode(&snap.key_bytes)?;
        if key != slot.key {
            return Err(format!("snapshot {} belongs to another signature", path.display()));
        }
        // A wrong-dimension index would panic on the next insert — inside
        // the held sequencer turn, wedging the signature's lane. Reject.
        if snap.dim != slot.key.k {
            return Err(format!(
                "snapshot {} dim {} != signature k {}",
                path.display(),
                snap.dim,
                slot.key.k
            ));
        }
        *index = snap.build();
        slot.reset_mutations();
        Ok(snap.items.len() as u64)
    }

    /// Load the **newest** snapshot of every signature in `dir` into the
    /// registry (crash recovery at startup, before traffic): rotation
    /// keeps up to `snapshot_keep` sequenced files per signature, and
    /// recovery reads only the highest sequence of each. A corrupt or
    /// foreign newest file fails the whole restore — a half-recovered
    /// corpus silently serving wrong results is worse than a loud startup
    /// error (older rotations stay on disk for manual recovery). Returns
    /// `(signatures, total items)` restored.
    pub fn restore_all(&self, dir: &Path) -> std::result::Result<(usize, u64), String> {
        let paths: Vec<PathBuf> = std::fs::read_dir(dir)
            .map_err(|e| format!("read {}: {e}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "snap"))
            .collect();
        // Newest sequence per signature stem (legacy unsequenced files
        // read as sequence 0, so a sequenced successor supersedes them).
        let mut newest: HashMap<String, (u64, PathBuf)> = HashMap::new();
        for path in paths {
            let name = match path.file_name().and_then(|s| s.to_str()) {
                Some(n) => n.to_string(),
                None => continue,
            };
            let (stem, seq) = match parse_snap_name(&name) {
                Some(parts) => parts,
                None => continue,
            };
            let supersedes = match newest.get(&stem) {
                Some((best, _)) => seq > *best,
                None => true,
            };
            if supersedes {
                newest.insert(stem, (seq, path));
            }
        }
        let mut loads: Vec<&(u64, PathBuf)> = newest.values().collect();
        loads.sort();
        let mut indexes = self.indexes.lock().unwrap();
        let mut items = 0u64;
        for (_, path) in loads {
            let snap =
                IndexSnapshot::read(path).map_err(|e| format!("{}: {e}", path.display()))?;
            let key = MapKey::decode(&snap.key_bytes)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            if snap.dim != key.k {
                return Err(format!(
                    "{}: snapshot dim {} != signature k {}",
                    path.display(),
                    snap.dim,
                    key.k
                ));
            }
            items += snap.items.len() as u64;
            let slot = Arc::new(IndexSlot::new(key.clone(), snap.build()));
            indexes.insert(key, slot);
        }
        Ok((newest.len(), items))
    }

    /// Number of live indexes.
    pub fn len(&self) -> usize {
        self.indexes.lock().unwrap().len()
    }

    /// True when no index has been created yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{AnyTensor, TtTensor};

    fn tt_key() -> MapKey {
        MapKey { kind: MapKind::Tt { rank: 2 }, dims: vec![3; 4], k: 6 }
    }

    #[test]
    fn same_key_returns_same_map() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key());
        let b = reg.get_or_create(&tt_key());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn same_master_seed_reproduces_identical_maps() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(42).get_or_create(&tt_key()).map.project(&x);
        let y2 = ProjectionRegistry::new(42).get_or_create(&tt_key()).map.project(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn different_master_seed_differs() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(1).get_or_create(&tt_key()).map.project(&x);
        let y2 = ProjectionRegistry::new(2).get_or_create(&tt_key()).map.project(&x);
        assert_ne!(y1, y2);
    }

    #[test]
    fn different_keys_get_different_maps() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key());
        let mut k2 = tt_key();
        k2.k = 7;
        let b = reg.get_or_create(&k2);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn kinds_are_distinguished_in_seeding() {
        let reg = ProjectionRegistry::new(0);
        let tt = MapKey { kind: MapKind::Tt { rank: 3 }, dims: vec![4; 3], k: 5 };
        let cp = MapKey { kind: MapKind::Cp { rank: 3 }, dims: vec![4; 3], k: 5 };
        assert_ne!(reg.seed_for(&tt), reg.seed_for(&cp));
    }

    #[test]
    fn buffer_pool_recycles_allocations() {
        let pool = WorkspacePool::new();
        let buf = pool.acquire_buf(32);
        assert_eq!(buf.len(), 32);
        assert!(buf.iter().all(|&v| v == 0.0));
        pool.release_buf(buf);
        assert_eq!(pool.idle_bufs(), 1);
        // Reacquire at a different size: same allocation, new length, and
        // the contents are zeroed again.
        let mut buf = pool.acquire_buf(8);
        assert_eq!(pool.idle_bufs(), 0);
        assert_eq!(buf.len(), 8);
        buf[0] = 7.0;
        pool.release_buf(buf);
        let buf = pool.acquire_buf(8);
        assert!(buf.iter().all(|&v| v == 0.0), "recycled buffers are re-zeroed");
        pool.release_buf(buf);
        // A grossly oversized pooled buffer is not handed out for a tiny
        // request (its capacity would leave the process inside a reply).
        pool.release_buf(vec![0.0; 4096]);
        let tiny = pool.acquire_buf(4);
        assert!(tiny.capacity() < 4096, "flush-sized buffer must not back a tiny reply");
    }

    #[test]
    fn index_registry_returns_same_index_for_same_key() {
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        assert!(reg.is_empty());
        let a = reg.get_or_create(&tt_key());
        let b = reg.get_or_create(&tt_key());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        assert_eq!(a.index.lock().unwrap().dim(), tt_key().k);
    }

    #[test]
    fn index_slot_runs_tickets_in_issue_order() {
        let reg = IndexRegistry::new(
            1,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        let slot = reg.get_or_create(&tt_key());
        let t0 = slot.issue_ticket();
        let t1 = slot.issue_ticket();
        assert_eq!((t0, t1), (0, 1));
        let log = Arc::new(Mutex::new(Vec::new()));
        // Run the *later* ticket on another thread first: it must block
        // until the earlier ticket completes.
        let handle = {
            let slot = Arc::clone(&slot);
            let log = Arc::clone(&log);
            std::thread::spawn(move || {
                slot.run_in_turn(t1, |_| log.lock().unwrap().push(1));
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        slot.run_in_turn(t0, |_| log.lock().unwrap().push(0));
        handle.join().unwrap();
        assert_eq!(*log.lock().unwrap(), vec![0, 1]);
    }

    #[test]
    fn map_key_encoding_roundtrips() {
        let keys = [
            tt_key(),
            MapKey { kind: MapKind::Cp { rank: 7 }, dims: vec![2, 5, 2], k: 9 },
            MapKey { kind: MapKind::Gaussian, dims: vec![15; 3], k: 64 },
            MapKey { kind: MapKind::VerySparse, dims: vec![1 << 12], k: 32 },
        ];
        for key in keys {
            assert_eq!(MapKey::decode(&key.encode()).unwrap(), key);
        }
        assert!(MapKey::decode(&[]).is_err());
        assert!(MapKey::decode(&[9; 30]).is_err(), "garbage header rejected");
        let mut bytes = tt_key().encode();
        bytes[0] = 9;
        assert!(MapKey::decode(&bytes).is_err(), "unknown kind tag rejected");
        bytes[0] = 1;
        bytes.push(0);
        assert!(MapKey::decode(&bytes).is_err(), "trailing bytes rejected");
    }

    #[test]
    fn registry_snapshot_roundtrips_through_disk() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_snap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Lsh,
            crate::index::LshConfig { tables: 3, bits: 5, probes: 2 },
        )
        .with_snapshot_dir(Some(dir.clone()));
        let slot = reg.get_or_create(&tt_key());
        let mut rng = Rng::seed_from(4);
        let qs: Vec<Vec<f64>> = (0..4).map(|_| rng.gaussian_vec(tt_key().k, 1.0)).collect();
        let report = {
            let mut index = slot.index.lock().unwrap();
            for i in 0..12u64 {
                index.insert(i, &rng.gaussian_vec(tt_key().k, 1.0));
            }
            reg.snapshot_slot(&slot, index.as_ref()).unwrap()
        };
        assert_eq!(report.items, 12);
        assert!(report.bytes > 0);
        // A fresh registry (same master seed) restores bit-identically.
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Lsh,
            crate::index::LshConfig { tables: 3, bits: 5, probes: 2 },
        );
        let (sigs, items) = reg2.restore_all(&dir).unwrap();
        assert_eq!((sigs, items), (1, 12));
        let slot2 = reg2.get_or_create(&tt_key());
        let mut ws = crate::projections::Workspace::new();
        let mut ws2 = crate::projections::Workspace::new();
        for q in &qs {
            assert_eq!(
                slot.index.lock().unwrap().query(q, 3, &mut ws),
                slot2.index.lock().unwrap().query(q, 3, &mut ws2),
            );
        }
        // Without a snapshot_dir the ops fail loudly instead of writing
        // somewhere surprising.
        let slot3 = reg2.get_or_create(&tt_key());
        let mut index3 = slot3.index.lock().unwrap();
        assert!(reg2.snapshot_slot(&slot3, index3.as_ref()).is_err());
        assert!(reg2.restore_slot(&slot3, &mut index3).is_err());
        drop(index3);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_rotation_keeps_last_n_and_restores_newest() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_rot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()))
        .with_snapshot_keep(2);
        let slot = reg.get_or_create(&tt_key());
        for round in 0..3u64 {
            let mut index = slot.index.lock().unwrap();
            index.insert(round, &vec![round as f64; tt_key().k]);
            reg.snapshot_slot(&slot, index.as_ref()).unwrap();
        }
        // Three writes, rotation depth 2: the two newest sequences remain.
        let prefix = snapshot_prefix(&tt_key());
        let snaps = list_snapshots(&dir, &prefix).unwrap();
        let seqs: Vec<u64> = snaps.iter().map(|(s, _)| *s).collect();
        assert_eq!(seqs, vec![2, 3], "oldest snapshot must be pruned");
        // restore_slot reads the newest cut (all three items).
        {
            let mut index = slot.index.lock().unwrap();
            index.remove(0);
            let restored = reg.restore_slot(&slot, &mut index).unwrap();
            assert_eq!(restored, 3);
            assert_eq!(index.len(), 3);
            // Counters restored from the capture, not the rebuild.
            assert_eq!(index.stats().inserts, 3);
        }
        // Startup recovery also picks the newest sequence per signature.
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        assert_eq!(reg2.restore_all(&dir).unwrap(), (1, 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_unsequenced_snapshots_still_restore() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_legacy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()));
        let slot = reg.get_or_create(&tt_key());
        // Write a PR 3-era file: `<prefix>.snap`, no sequence.
        {
            let mut index = slot.index.lock().unwrap();
            index.insert(1, &vec![1.0; tt_key().k]);
            let snap = crate::index::IndexSnapshot::capture(slot.key.encode(), index.as_ref());
            let legacy = dir.join(format!("{}.snap", snapshot_prefix(&tt_key())));
            snap.write_atomic(&legacy).unwrap();
            index.insert(2, &vec![2.0; tt_key().k]);
            // The legacy file reads as sequence 0, so restore finds it…
            let restored = reg.restore_slot(&slot, &mut index).unwrap();
            assert_eq!(restored, 1);
            // …and the next rotation write supersedes it with sequence 1.
            index.insert(3, &vec![3.0; tt_key().k]);
            reg.snapshot_slot(&slot, index.as_ref()).unwrap();
            let restored = reg.restore_slot(&slot, &mut index).unwrap();
            assert_eq!(restored, 2, "sequenced snapshot supersedes the legacy file");
        }
        assert_eq!(parse_snap_name("sig_ab.00000003.snap"), Some(("sig_ab".into(), 3)));
        assert_eq!(parse_snap_name("sig_ab.snap"), Some(("sig_ab".into(), 0)));
        assert_eq!(parse_snap_name("notes.txt"), None);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn index_seed_differs_from_map_seed() {
        // The LSH hyperplane stream must not reuse the projection map's
        // stream for the same key.
        let key = tt_key();
        assert_ne!(map_key_seed(7, &key), map_key_seed(7 ^ 0xA11_1DE8_5EED, &key));
    }
}
