//! Projection-map state: the registry of drawn maps.
//!
//! A serving deployment must answer every request for the same signature
//! with the *same* random map — otherwise embeddings are not comparable
//! across requests. The registry derives each map's seed deterministically
//! from `(master_seed, map key)`, so a restarted coordinator reproduces
//! identical maps, and the PJRT and native paths share one draw.

use crate::projections::{
    CpProjection, GaussianProjection, Projection, SparseKind, SparseProjection, TtProjection,
    Workspace,
};
use crate::rng::Rng;
use crate::runtime::{pack, ArtifactKind, ArtifactSpec};
use anyhow::Result;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Which projection family a registry entry uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MapKind {
    /// `f_TT(R)`.
    Tt {
        /// TT rank R.
        rank: usize,
    },
    /// `f_CP(R)`.
    Cp {
        /// CP rank R.
        rank: usize,
    },
    /// Dense Gaussian RP.
    Gaussian,
    /// Very sparse RP (Li et al.).
    VerySparse,
}

/// Registry key: one map per (kind, input dims, k).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MapKey {
    /// Map family + rank.
    pub kind: MapKind,
    /// Input mode sizes.
    pub dims: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
}

/// Cached PJRT parameter buffers for one map (packed once, reused for
/// every batch).
#[derive(Debug, Clone)]
pub enum PackedParams {
    /// `(g_first, g_mid, g_last)` for TT artifacts.
    Tt(Arc<(Vec<f32>, Vec<f32>, Vec<f32>)>),
    /// `a` for CP artifacts.
    Cp(Arc<Vec<f32>>),
    /// `w` for dense artifacts.
    Dense(Arc<Vec<f32>>),
}

/// A registry entry: the map plus optional packed parameters.
pub struct MapEntry {
    /// The projection map (native execution).
    pub map: Arc<dyn Projection>,
    /// Packed PJRT parameters, present when an artifact matches this map.
    pub packed: Option<PackedParams>,
}

/// Pool of reusable projection [`Workspace`]s for the worker threads.
///
/// A worker acquires a workspace for the duration of one batch and
/// releases it afterwards; each workspace's buffers warm up to the
/// high-water batch size, so steady-state native batches perform no
/// allocation inside the projection kernels. The pool never shrinks —
/// its population is bounded by the worker count (a worker holds at most
/// one workspace at a time).
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
}

impl WorkspacePool {
    /// New empty pool (workspaces are created lazily on first acquire).
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a warm workspace, or a fresh one when the pool is empty.
    pub fn acquire(&self) -> Workspace {
        self.free.lock().unwrap().pop().unwrap_or_default()
    }

    /// Return a workspace for reuse.
    pub fn release(&self, ws: Workspace) {
        self.free.lock().unwrap().push(ws);
    }

    /// Number of idle pooled workspaces.
    pub fn idle(&self) -> usize {
        self.free.lock().unwrap().len()
    }
}

/// Deterministic, thread-safe projection-map registry.
pub struct ProjectionRegistry {
    master_seed: u64,
    maps: Mutex<HashMap<MapKey, Arc<MapEntry>>>,
}

impl ProjectionRegistry {
    /// New registry; all map draws derive from `master_seed`.
    pub fn new(master_seed: u64) -> Self {
        Self { master_seed, maps: Mutex::new(HashMap::new()) }
    }

    /// Stable per-key seed: hash the key fields into the master seed.
    fn seed_for(&self, key: &MapKey) -> u64 {
        // FNV-1a over the key's canonical encoding, mixed with the master.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ self.master_seed;
        let mut eat = |x: u64| {
            h ^= x;
            h = h.wrapping_mul(0x1_0000_0000_01b3);
        };
        match key.kind {
            MapKind::Tt { rank } => {
                eat(1);
                eat(rank as u64);
            }
            MapKind::Cp { rank } => {
                eat(2);
                eat(rank as u64);
            }
            MapKind::Gaussian => eat(3),
            MapKind::VerySparse => eat(4),
        }
        for &d in &key.dims {
            eat(d as u64);
        }
        eat(key.k as u64);
        h
    }

    /// Get or create the map for `key` (no PJRT packing).
    pub fn get_or_create(&self, key: &MapKey) -> Arc<MapEntry> {
        self.get_or_create_inner(key, None).expect("native map creation cannot fail")
    }

    /// Get or create the map for `key`, packing parameters for `spec`'s
    /// artifact layout on first creation.
    pub fn get_or_create_for_artifact(
        &self,
        key: &MapKey,
        spec: &ArtifactSpec,
    ) -> Result<Arc<MapEntry>> {
        self.get_or_create_inner(key, Some(spec))
    }

    fn get_or_create_inner(
        &self,
        key: &MapKey,
        spec: Option<&ArtifactSpec>,
    ) -> Result<Arc<MapEntry>> {
        let mut maps = self.maps.lock().unwrap();
        if let Some(e) = maps.get(key) {
            // Upgrade an existing entry with packing if newly needed.
            if e.packed.is_some() || spec.is_none() {
                return Ok(Arc::clone(e));
            }
        }
        let mut rng = Rng::seed_from(self.seed_for(key));
        let (map, packed): (Arc<dyn Projection>, Option<PackedParams>) = match key.kind {
            MapKind::Tt { rank } => {
                let f = TtProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Tt => {
                        let (n, d, r, _) = s.tt_meta()?;
                        Some(PackedParams::Tt(Arc::new(pack::pack_tt_projection(
                            &f, n, d, r,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Cp { rank } => {
                let f = CpProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Cp => {
                        let n = s.n_modes.unwrap();
                        let d = s.dim.unwrap();
                        Some(PackedParams::Cp(Arc::new(pack::pack_cp_projection(
                            &f, n, d, rank,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Gaussian => {
                let f = GaussianProjection::new(&key.dims, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Dense => {
                        Some(PackedParams::Dense(Arc::new(pack::pack_dense_projection(&f))))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::VerySparse => {
                let f = SparseProjection::new(&key.dims, key.k, SparseKind::VerySparse, &mut rng);
                (Arc::new(f), None)
            }
        };
        let entry = Arc::new(MapEntry { map, packed });
        maps.insert(key.clone(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Number of registered maps.
    pub fn len(&self) -> usize {
        self.maps.lock().unwrap().len()
    }

    /// True when no maps have been drawn yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{AnyTensor, TtTensor};

    fn tt_key() -> MapKey {
        MapKey { kind: MapKind::Tt { rank: 2 }, dims: vec![3; 4], k: 6 }
    }

    #[test]
    fn same_key_returns_same_map() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key());
        let b = reg.get_or_create(&tt_key());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn same_master_seed_reproduces_identical_maps() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(42).get_or_create(&tt_key()).map.project(&x);
        let y2 = ProjectionRegistry::new(42).get_or_create(&tt_key()).map.project(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn different_master_seed_differs() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(1).get_or_create(&tt_key()).map.project(&x);
        let y2 = ProjectionRegistry::new(2).get_or_create(&tt_key()).map.project(&x);
        assert_ne!(y1, y2);
    }

    #[test]
    fn different_keys_get_different_maps() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key());
        let mut k2 = tt_key();
        k2.k = 7;
        let b = reg.get_or_create(&k2);
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn kinds_are_distinguished_in_seeding() {
        let reg = ProjectionRegistry::new(0);
        let tt = MapKey { kind: MapKind::Tt { rank: 3 }, dims: vec![4; 3], k: 5 };
        let cp = MapKey { kind: MapKind::Cp { rank: 3 }, dims: vec![4; 3], k: 5 };
        assert_ne!(reg.seed_for(&tt), reg.seed_for(&cp));
    }
}
