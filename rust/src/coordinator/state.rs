//! Projection-map state: the registry of drawn maps.
//!
//! A serving deployment must answer every request for the same signature
//! with the *same* random map — otherwise embeddings are not comparable
//! across requests. The registry derives each map's seed deterministically
//! from `(master_seed, map key)`, so a restarted coordinator reproduces
//! identical maps, and the PJRT and native paths share one draw.

use crate::index::persist::{self, Cursor, ManifestShard, ShardManifest};
use crate::index::{
    build_index, shard_of, wal, AnnIndex, BackendKind, IndexSnapshot, LshConfig, SnapshotReport,
    WalConfig, WalFsync, WalWriter,
};
use crate::projections::{
    CpProjection, GaussianProjection, Projection, SparseKind, SparseProjection, TtProjection,
    Workspace,
};
use crate::rng::Rng;
use crate::runtime::{pack, ArtifactKind, ArtifactSpec};
use crate::util::sync::{lock_recover, wait_recover};
use anyhow::{anyhow, Result};
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Which projection family a registry entry uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MapKind {
    /// `f_TT(R)`.
    Tt {
        /// TT rank R.
        rank: usize,
    },
    /// `f_CP(R)`.
    Cp {
        /// CP rank R.
        rank: usize,
    },
    /// Dense Gaussian RP.
    Gaussian,
    /// Very sparse RP (Li et al.).
    VerySparse,
}

/// Registry key: one map per (kind, input dims, k).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MapKey {
    /// Map family + rank.
    pub kind: MapKind,
    /// Input mode sizes.
    pub dims: Vec<usize>,
    /// Embedding dimension.
    pub k: usize,
}

impl MapKey {
    /// Human-readable signature label, the key of the observability
    /// registry and the `sig` label of every exported per-signature
    /// metric (e.g. `tt-r5/3x3x3/k64`).
    pub fn label(&self) -> String {
        let kind = match self.kind {
            MapKind::Tt { rank } => format!("tt-r{rank}"),
            MapKind::Cp { rank } => format!("cp-r{rank}"),
            MapKind::Gaussian => "gaussian".to_string(),
            MapKind::VerySparse => "verysparse".to_string(),
        };
        let dims =
            self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x");
        format!("{kind}/{dims}/k{}", self.k)
    }

    /// Canonical byte encoding, embedded in index snapshot headers so a
    /// restored file routes back to its signature:
    /// `kind tag u8 | rank u64 | ndims u32 | dims u64… | k u64` (LE).
    pub fn encode(&self) -> Vec<u8> {
        let (tag, rank): (u8, u64) = match self.kind {
            MapKind::Tt { rank } => (1, rank as u64),
            MapKind::Cp { rank } => (2, rank as u64),
            MapKind::Gaussian => (3, 0),
            MapKind::VerySparse => (4, 0),
        };
        let mut out = Vec::with_capacity(1 + 8 + 4 + self.dims.len() * 8 + 8);
        out.push(tag);
        out.extend_from_slice(&rank.to_le_bytes());
        out.extend_from_slice(&(self.dims.len() as u32).to_le_bytes());
        for &d in &self.dims {
            out.extend_from_slice(&(d as u64).to_le_bytes());
        }
        out.extend_from_slice(&(self.k as u64).to_le_bytes());
        out
    }

    /// Inverse of [`MapKey::encode`] (reads through the persistence
    /// layer's bounds-checked [`Cursor`]).
    pub fn decode(bytes: &[u8]) -> std::result::Result<MapKey, String> {
        let mut cur = Cursor::new(bytes);
        let tag = cur.u8()?;
        let rank = cur.u64()? as usize;
        let ndims = cur.u32()? as usize;
        // Validate the advertised length before allocating for it (this
        // also rejects trailing bytes).
        if bytes.len() != 13 + ndims * 8 + 8 {
            return Err("map key length mismatch".into());
        }
        let mut dims = Vec::with_capacity(ndims);
        for _ in 0..ndims {
            dims.push(cur.u64()? as usize);
        }
        let k = cur.u64()? as usize;
        let kind = match tag {
            1 => MapKind::Tt { rank },
            2 => MapKind::Cp { rank },
            3 => MapKind::Gaussian,
            4 => MapKind::VerySparse,
            other => return Err(format!("unknown map kind tag {other}")),
        };
        Ok(MapKey { kind, dims, k })
    }
}

/// Cached PJRT parameter buffers for one map (packed once, reused for
/// every batch).
#[derive(Debug, Clone)]
pub enum PackedParams {
    /// `(g_first, g_mid, g_last)` for TT artifacts.
    Tt(Arc<(Vec<f32>, Vec<f32>, Vec<f32>)>),
    /// `a` for CP artifacts.
    Cp(Arc<Vec<f32>>),
    /// `w` for dense artifacts.
    Dense(Arc<Vec<f32>>),
}

/// A registry entry: the map plus optional packed parameters.
pub struct MapEntry {
    /// The projection map (native execution).
    pub map: Arc<dyn Projection>,
    /// Packed PJRT parameters, present when an artifact matches this map.
    pub packed: Option<PackedParams>,
}

/// Pool of reusable projection [`Workspace`]s for the worker threads.
///
/// A worker acquires a workspace for the duration of one batch and
/// releases it afterwards; each workspace's buffers warm up to the
/// high-water batch size, so steady-state native batches perform no
/// allocation inside the projection kernels. The pool never shrinks —
/// its population is bounded by the worker count (a worker holds at most
/// one workspace at a time).
#[derive(Default)]
pub struct WorkspacePool {
    free: Mutex<Vec<Workspace>>,
    /// Recyclable `f64` buffers: flushed-batch `out` buffers and the
    /// index path's query-staging buffers (the buffers that come back;
    /// per-reply embeddings leave the process inside responses and are
    /// deliberately not pooled).
    bufs: Mutex<Vec<Vec<f64>>>,
}

/// Cap on pooled `f64` buffers: the pool only has to cover the in-flight
/// flushes of the worker pool.
const MAX_POOLED_BUFS: usize = 64;

impl WorkspacePool {
    /// New empty pool (workspaces are created lazily on first acquire).
    pub fn new() -> Self {
        Self::default()
    }

    /// Take a warm workspace, or a fresh one when the pool is empty.
    pub fn acquire(&self) -> Workspace {
        lock_recover(&self.free).pop().unwrap_or_default()
    }

    /// Return a workspace for reuse.
    pub fn release(&self, ws: Workspace) {
        lock_recover(&self.free).push(ws);
    }

    /// Number of idle pooled workspaces.
    pub fn idle(&self) -> usize {
        lock_recover(&self.free).len()
    }

    /// Take a zeroed `len`-element buffer, reusing a pooled allocation
    /// when one of a fitting size exists (steady-state flushes allocate
    /// nothing). "Fitting" bounds the over-capacity: a flush-sized buffer
    /// must not be handed out as a `k`-sized reply embedding, or its full
    /// capacity leaves the process inside the response.
    pub fn acquire_buf(&self, len: usize) -> Vec<f64> {
        let mut bufs = lock_recover(&self.bufs);
        let fit = bufs
            .iter()
            .position(|b| b.capacity() >= len && b.capacity() <= len.saturating_mul(4).max(64));
        let mut buf = match fit {
            Some(i) => bufs.swap_remove(i),
            None => Vec::new(),
        };
        drop(bufs);
        buf.clear();
        buf.resize(len, 0.0);
        buf
    }

    /// Return a buffer for reuse. Buffers handed to clients inside a
    /// response never come back — only flush `out` buffers and embeddings
    /// whose reply channel was dropped are recycled — so the pool is
    /// bounded by [`MAX_POOLED_BUFS`] and excess buffers are simply freed.
    pub fn release_buf(&self, buf: Vec<f64>) {
        let mut bufs = lock_recover(&self.bufs);
        if bufs.len() < MAX_POOLED_BUFS {
            bufs.push(buf);
        }
    }

    /// Number of idle pooled buffers.
    pub fn idle_bufs(&self) -> usize {
        lock_recover(&self.bufs).len()
    }
}

/// Stable seed for a map key: FNV-1a over the key's canonical encoding,
/// mixed with `master_seed`. Shared by the projection and index
/// registries (the index registry perturbs the master so hash hyperplanes
/// never reuse a projection map's stream).
fn map_key_seed(master_seed: u64, key: &MapKey) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325 ^ master_seed;
    let mut eat = |x: u64| {
        h ^= x;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    };
    match key.kind {
        MapKind::Tt { rank } => {
            eat(1);
            eat(rank as u64);
        }
        MapKind::Cp { rank } => {
            eat(2);
            eat(rank as u64);
        }
        MapKind::Gaussian => eat(3),
        MapKind::VerySparse => eat(4),
    }
    for &d in &key.dims {
        eat(d as u64);
    }
    eat(key.k as u64);
    h
}

/// Deterministic, thread-safe projection-map registry.
pub struct ProjectionRegistry {
    master_seed: u64,
    maps: Mutex<HashMap<MapKey, Arc<MapEntry>>>,
}

impl ProjectionRegistry {
    /// New registry; all map draws derive from `master_seed`.
    pub fn new(master_seed: u64) -> Self {
        Self { master_seed, maps: Mutex::new(HashMap::new()) }
    }

    /// Stable per-key seed: hash the key fields into the master seed.
    fn seed_for(&self, key: &MapKey) -> u64 {
        map_key_seed(self.master_seed, key)
    }

    /// Get or create the map for `key` (no PJRT packing). Native map
    /// creation is infallible today, but the fallible signature keeps the
    /// worker path free of panics: a future failure mode becomes an error
    /// reply, not a dead worker.
    pub fn get_or_create(&self, key: &MapKey) -> Result<Arc<MapEntry>> {
        self.get_or_create_inner(key, None)
    }

    /// Get or create the map for `key`, packing parameters for `spec`'s
    /// artifact layout on first creation.
    pub fn get_or_create_for_artifact(
        &self,
        key: &MapKey,
        spec: &ArtifactSpec,
    ) -> Result<Arc<MapEntry>> {
        self.get_or_create_inner(key, Some(spec))
    }

    fn get_or_create_inner(
        &self,
        key: &MapKey,
        spec: Option<&ArtifactSpec>,
    ) -> Result<Arc<MapEntry>> {
        let mut maps = lock_recover(&self.maps);
        if let Some(e) = maps.get(key) {
            // Upgrade an existing entry with packing if newly needed.
            if e.packed.is_some() || spec.is_none() {
                return Ok(Arc::clone(e));
            }
        }
        let mut rng = Rng::seed_from(self.seed_for(key));
        let (map, packed): (Arc<dyn Projection>, Option<PackedParams>) = match key.kind {
            MapKind::Tt { rank } => {
                let f = TtProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Tt => {
                        let (n, d, r, _) = s.tt_meta()?;
                        Some(PackedParams::Tt(Arc::new(pack::pack_tt_projection(
                            &f, n, d, r,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Cp { rank } => {
                let f = CpProjection::new(&key.dims, rank, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Cp => {
                        let n = s.n_modes.ok_or_else(|| anyhow!("CP artifact missing n_modes"))?;
                        let d = s.dim.ok_or_else(|| anyhow!("CP artifact missing dim"))?;
                        Some(PackedParams::Cp(Arc::new(pack::pack_cp_projection(
                            &f, n, d, rank,
                        )?)))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::Gaussian => {
                let f = GaussianProjection::new(&key.dims, key.k, &mut rng);
                let packed = match spec {
                    Some(s) if s.kind == ArtifactKind::Dense => {
                        Some(PackedParams::Dense(Arc::new(pack::pack_dense_projection(&f))))
                    }
                    _ => None,
                };
                (Arc::new(f), packed)
            }
            MapKind::VerySparse => {
                let f = SparseProjection::new(&key.dims, key.k, SparseKind::VerySparse, &mut rng);
                (Arc::new(f), None)
            }
        };
        let entry = Arc::new(MapEntry { map, packed });
        maps.insert(key.clone(), Arc::clone(&entry));
        Ok(entry)
    }

    /// Number of registered maps.
    pub fn len(&self) -> usize {
        lock_recover(&self.maps).len()
    }

    /// True when no maps have been drawn yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One shard's execution lane: the backend index plus the FIFO sequencer
/// state that orders the shard's passes across flushes.
struct ShardLane {
    /// The shard's backend index.
    index: Mutex<Box<dyn AnnIndex>>,
    /// Next ticket allowed to run its pass on this lane.
    turn: Mutex<u64>,
    turn_done: Condvar,
    /// Tickets handed out so far on this lane.
    issued: AtomicU64,
    /// Live items after the lane's most recent completed pass (feeds the
    /// `index_shard_max_skew` gauge without locking the index).
    len: AtomicU64,
    /// Lifetime effective mutations applied to this lane, incremented
    /// *inside* the lane's turn — so a cut reading it during its own
    /// pass observes exactly the mutations its capture covers.
    noted: AtomicU64,
    /// Watermark of [`ShardLane::noted`] covered by the newest successful
    /// snapshot/restore. Advanced by `fetch_max`, so overlapping cuts
    /// commute: the pending count `noted − covered` can never be wiped by
    /// a stale baseline (mutations a cut did not capture stay pending).
    covered: AtomicU64,
    /// This lane's WAL append handle (`None` when the WAL is off). The
    /// inner `Result` turns a failed writer open into per-op error
    /// replies instead of a panic on the serving path. Appended to only
    /// inside the lane's sequencer turn; synced and truncated off-turn
    /// (its own mutex, never held together with the index lock by those
    /// callers, so no lock-order inversion).
    wal: Option<Mutex<std::result::Result<WalWriter, String>>>,
    /// Last appended WAL seq, mirrored out of the writer so gauges and
    /// in-turn mark capture read it without the WAL mutex.
    wal_seq: AtomicU64,
    /// Highest WAL seq covered by a durable checkpoint (`fetch_max`, like
    /// [`ShardLane::covered`]). `wal_seq − wal_covered` is the lane's
    /// replay cost — the `index_wal_lag` gauge.
    wal_covered: AtomicU64,
}

/// One signature's sharded ANN index: `S` backend shards, each behind its
/// own FIFO sequencer lane, under a signature-level epoch barrier.
///
/// Flushes for one signature are dispatched in arrival order but execute
/// on different pool workers. The dispatcher reserves a ticket on every
/// lane the flush touches ([`IndexSlot::issue_tickets`], called in
/// dispatch order from the single dispatcher thread); the worker runs one
/// pass per touched shard, in ascending shard order, each inside
/// [`IndexSlot::run_shard_turn`], which blocks until every earlier ticket
/// on that lane has completed.
///
/// **Ordering.** Conflicting ops on the same id always hash to the same
/// shard ([`crate::index::shard_of`]), and that lane's tickets are issued
/// in dispatch (= arrival) order, so same-id pairs can never reorder.
/// Queries scatter: they hold a ticket on *every* lane (the signature-
/// level epoch barrier), so each shard scores a query at exactly the
/// query's arrival position in that shard's mutation stream — releasing
/// lane `s` before acquiring lane `s + 1` is safe because a later op
/// holds later tickets on every lane it touches and therefore still
/// observes the barrier op's effects (or pre-state) consistently.
/// Snapshot and restore ops ride the same barrier, which is what makes a
/// capture a consistent cut without ever freezing all lanes at once.
///
/// **Liveness.** The pool dequeues jobs FIFO and lane tickets are issued
/// in dispatch order, so the earliest unfinished flush holds the head
/// ticket of every lane it waits on; it always progresses, hence no
/// deadlock — the same argument as the PR 2 single-lane design, per lane.
pub struct IndexSlot {
    /// The signature this index serves (snapshot files are keyed on it).
    pub key: MapKey,
    /// Per-shard lanes (length ≥ 1; 1 = the unsharded special case).
    lanes: Vec<ShardLane>,
    /// Shard passes currently executing (across all lanes).
    active_passes: AtomicU64,
    /// High-water of [`IndexSlot::active_passes`] — proves index phases
    /// of one signature ran on more than one worker at once.
    parallel_high_water: AtomicU64,
    /// Serializes this signature's off-turn snapshot writes and restore
    /// reads: sequence numbers are picked from a directory listing, so
    /// two concurrent writers (pipelined explicit snapshots, or explicit
    /// + periodic from adjacent flushes) could otherwise claim the same
    /// sequence and interleave renames into a corrupt newest sequence.
    /// Never held while a lane turn is held, so serving is unaffected.
    snapshot_io: Mutex<()>,
}

impl IndexSlot {
    fn new(key: MapKey, shards: Vec<Box<dyn AnnIndex>>) -> Self {
        Self::new_with_wal(key, shards, None)
    }

    /// Like [`IndexSlot::new`], attaching one WAL writer per lane when
    /// `wals` is present (`wals.len()` must equal the shard count). Every
    /// writer arrives freshly opened with its covered watermark equal to
    /// its last seq (startup always runs [`IndexRegistry::recover_wal`]
    /// first), so the lag gauge starts at zero; an `Err` writer serves as
    /// a sentinel that fails that lane's mutations loudly.
    fn new_with_wal(
        key: MapKey,
        shards: Vec<Box<dyn AnnIndex>>,
        wals: Option<Vec<std::result::Result<WalWriter, String>>>,
    ) -> Self {
        assert!(!shards.is_empty(), "a slot needs at least one shard");
        if let Some(w) = &wals {
            assert!(w.len() == shards.len(), "one WAL writer per lane");
        }
        let mut wals: Vec<Option<std::result::Result<WalWriter, String>>> = match wals {
            Some(v) => v.into_iter().map(Some).collect(),
            None => (0..shards.len()).map(|_| None).collect(),
        };
        let lanes = shards
            .into_iter()
            .zip(wals.iter_mut())
            .map(|(index, wal_state)| {
                let len = index.len() as u64;
                let wal_state = wal_state.take();
                let seq = match &wal_state {
                    Some(Ok(w)) => w.seq(),
                    _ => 0,
                };
                ShardLane {
                    index: Mutex::new(index),
                    turn: Mutex::new(0),
                    turn_done: Condvar::new(),
                    issued: AtomicU64::new(0),
                    len: AtomicU64::new(len),
                    noted: AtomicU64::new(0),
                    covered: AtomicU64::new(0),
                    wal: wal_state.map(Mutex::new),
                    wal_seq: AtomicU64::new(seq),
                    wal_covered: AtomicU64::new(seq),
                }
            })
            .collect();
        Self {
            key,
            lanes,
            active_passes: AtomicU64::new(0),
            parallel_high_water: AtomicU64::new(0),
            snapshot_io: Mutex::new(()),
        }
    }

    /// Number of shards (= lanes).
    pub fn shards(&self) -> usize {
        self.lanes.len()
    }

    /// Record `n` effective mutations applied to `shard`. Must be called
    /// while the lane's turn (or its index lock, out of band) is held, so
    /// a cut reading [`IndexSlot::shard_noted`] during its own pass on
    /// that lane observes exactly what its capture covers.
    pub fn note_shard_mutations(&self, shard: usize, n: u64) {
        self.lanes[shard].noted.fetch_add(n, Ordering::Relaxed);
    }

    /// Lifetime effective-mutation count of one lane (the cut watermark a
    /// snapshot/restore records at its arrival position).
    pub fn shard_noted(&self, shard: usize) -> u64 {
        self.lanes[shard].noted.load(Ordering::Relaxed)
    }

    /// Advance one lane's covered watermark after a successful
    /// snapshot/restore. `fetch_max` makes overlapping cuts commute —
    /// whichever write finishes last, the covered watermark ends at the
    /// newest cut, and mutations no cut captured stay pending (a plain
    /// subtract/reset could wipe counts noted during a slow off-turn
    /// write, silently widening the periodic-durability window).
    pub fn cover_shard(&self, shard: usize, watermark: u64) {
        self.lanes[shard].covered.fetch_max(watermark, Ordering::Relaxed);
    }

    /// Mutations not yet covered by any snapshot/restore cut — drives the
    /// `snapshot_every_ops` periodic trigger (approximate under
    /// concurrency; the trigger only needs a threshold).
    pub fn pending_mutations(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| {
                l.noted
                    .load(Ordering::Relaxed)
                    .saturating_sub(l.covered.load(Ordering::Relaxed))
            })
            .sum()
    }

    /// True when this slot's lanes log to a write-ahead log.
    pub fn wal_enabled(&self) -> bool {
        self.lanes.first().is_some_and(|l| l.wal.is_some())
    }

    /// Append one op to a lane's WAL. MUST be called inside the lane's
    /// sequencer turn — that is the whole durability design: replay order
    /// equals arrival order because the log is written at the op's
    /// arrival position. Returns the record's seq, or `None` when the WAL
    /// is off. Durability requires a later [`IndexSlot::wal_commit`].
    pub fn wal_append(
        &self,
        shard: usize,
        op: u8,
        id: u64,
        payload: &[f64],
    ) -> std::result::Result<Option<u64>, String> {
        let lane = &self.lanes[shard];
        let Some(w) = &lane.wal else { return Ok(None) };
        let mut guard = lock_recover(w);
        let writer = guard.as_mut().map_err(|e| e.clone())?;
        let seq = writer.append(op, id, payload)?;
        lane.wal_seq.store(seq, Ordering::Relaxed);
        Ok(Some(seq))
    }

    /// Group-commit point for one lane: `sync_data` its segment per the
    /// fsync policy (`Flush` syncs any unsynced appends; `EveryN` only
    /// once N accumulate). Called once per touched lane per coordinator
    /// flush — never per op. Returns whether a sync actually ran.
    pub fn wal_commit(
        &self,
        shard: usize,
        fsync: WalFsync,
    ) -> std::result::Result<bool, String> {
        let lane = &self.lanes[shard];
        let Some(w) = &lane.wal else { return Ok(false) };
        let mut guard = lock_recover(w);
        let writer = guard.as_mut().map_err(|e| e.clone())?;
        let due = match fsync {
            WalFsync::Flush => writer.unsynced() > 0,
            WalFsync::EveryN(n) => writer.unsynced() >= n,
        };
        if due {
            writer.sync()?;
        }
        Ok(due)
    }

    /// Last appended WAL seq of one lane (0 when nothing was logged).
    /// Read in-turn by snapshot cuts: the value is the checkpoint
    /// watermark the capture covers.
    pub fn wal_seq(&self, shard: usize) -> u64 {
        self.lanes[shard].wal_seq.load(Ordering::Relaxed)
    }

    /// Ops logged but not yet covered by a durable checkpoint, summed
    /// over lanes — the `index_wal_lag` gauge (replay cost on crash).
    pub fn wal_lag(&self) -> u64 {
        self.lanes
            .iter()
            .map(|l| {
                l.wal_seq
                    .load(Ordering::Relaxed)
                    .saturating_sub(l.wal_covered.load(Ordering::Relaxed))
            })
            .sum()
    }

    /// Advance one lane's WAL covered watermark after its checkpoint
    /// manifest was durably renamed, and truncate fully covered segments.
    /// Off-turn safe: takes only the lane's WAL mutex. Returns the number
    /// of deleted segments.
    pub fn wal_cover(&self, shard: usize, mark: u64) -> std::result::Result<usize, String> {
        let lane = &self.lanes[shard];
        let Some(w) = &lane.wal else { return Ok(0) };
        lane.wal_covered.fetch_max(mark, Ordering::Relaxed);
        let mut guard = lock_recover(w);
        let writer = guard.as_mut().map_err(|e| e.clone())?;
        writer.truncate_covered(mark)
    }

    /// Drop one lane's logged tail and start a fresh chain — the runtime
    /// `restore` op rewinds the index to the newest snapshot, so replay
    /// of the pre-restore tail over it would resurrect discarded ops.
    /// Called inside the lane's turn at the restore's arrival position;
    /// seq numbering continues, so post-restore records stay above every
    /// older checkpoint watermark.
    pub fn wal_reset(&self, shard: usize) -> std::result::Result<(), String> {
        let lane = &self.lanes[shard];
        let Some(w) = &lane.wal else { return Ok(()) };
        let mut guard = lock_recover(w);
        let writer = guard.as_mut().map_err(|e| e.clone())?;
        writer.reset()?;
        lane.wal_covered.fetch_max(writer.seq(), Ordering::Relaxed);
        Ok(())
    }


    /// Reserve the next position on each of the given lanes, in the order
    /// given (callers pass ascending shard ids). Call in dispatch order —
    /// the coordinator calls it from the single dispatcher thread, before
    /// submitting the flush to the worker pool — so every lane's ticket
    /// sequence equals arrival order.
    pub fn issue_tickets(&self, shards: &[usize]) -> Vec<(usize, u64)> {
        shards
            .iter()
            .map(|&s| (s, self.lanes[s].issued.fetch_add(1, Ordering::Relaxed)))
            .collect()
    }

    /// Reserve the next position on **every** lane — the signature-level
    /// epoch barrier (queries, stats, snapshot, restore).
    pub fn issue_barrier(&self) -> Vec<(usize, u64)> {
        self.issue_tickets(&(0..self.lanes.len()).collect::<Vec<usize>>())
    }

    /// Block until `ticket` is at the head of lane `shard`, run `f` on
    /// the locked shard index, then release the turn to the next ticket.
    /// The closure receives the owning `Box` so a `restore` op can swap
    /// the shard's index while the turn is held.
    ///
    /// Panic-safe: the turn advances (and waiters are notified) even when
    /// `f` panics, via a drop guard — a panicking pass must degrade to one
    /// failed request, not wedge every later ticket on the lane. Poisoned
    /// lane locks are recovered for the same reason.
    pub fn run_shard_turn<R>(
        &self,
        shard: usize,
        ticket: u64,
        f: impl FnOnce(&mut Box<dyn AnnIndex>) -> R,
    ) -> R {
        /// Advances the lane turn on drop, so an unwinding pass still
        /// releases the lane to the next ticket.
        struct TurnGuard<'a> {
            slot: &'a IndexSlot,
            lane: &'a ShardLane,
        }
        impl Drop for TurnGuard<'_> {
            fn drop(&mut self) {
                self.slot.active_passes.fetch_sub(1, Ordering::Relaxed);
                *lock_recover(&self.lane.turn) += 1;
                self.lane.turn_done.notify_all();
            }
        }

        let lane = &self.lanes[shard];
        let mut turn = lock_recover(&lane.turn);
        while *turn != ticket {
            turn = wait_recover(&lane.turn_done, turn);
        }
        // Release the turn mutex while the pass runs: only this thread's
        // ticket matches, so waiters that wake early just re-check and
        // block again. The drop guard below reacquires it to advance.
        drop(turn);
        let active = self.active_passes.fetch_add(1, Ordering::Relaxed) + 1;
        self.parallel_high_water.fetch_max(active, Ordering::Relaxed);
        let _turn_guard = TurnGuard { slot: self, lane };
        let mut index = lock_recover(&lane.index);
        let r = f(&mut index);
        lane.len.store(index.len() as u64, Ordering::Relaxed);
        drop(index);
        r
    }

    /// Lock one shard's index directly (out-of-band access for tests and
    /// ops tooling; coordinator flushes go through
    /// [`IndexSlot::run_shard_turn`]).
    pub fn lock_shard(&self, shard: usize) -> std::sync::MutexGuard<'_, Box<dyn AnnIndex>> {
        lock_recover(&self.lanes[shard].index)
    }

    /// Live item count per shard, as of each lane's last completed pass.
    pub fn shard_lens(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.len.load(Ordering::Relaxed)).collect()
    }

    /// Partition imbalance: `max − min` of the per-shard live counts (the
    /// `index_shard_max_skew` gauge; 0 for a single shard).
    pub fn max_skew(&self) -> u64 {
        let lens = self.shard_lens();
        match (lens.iter().max(), lens.iter().min()) {
            (Some(mx), Some(mn)) => mx - mn,
            _ => 0,
        }
    }

    /// High-water of concurrently executing shard passes since creation.
    pub fn parallel_high_water(&self) -> u64 {
        self.parallel_high_water.load(Ordering::Relaxed)
    }

    /// Shard passes executing right now (the current-value companion of
    /// [`IndexSlot::parallel_high_water`]).
    pub fn active_passes(&self) -> u64 {
        self.active_passes.load(Ordering::Relaxed)
    }
}

/// A per-signature index shared between the registry and worker jobs.
pub type SharedIndex = Arc<IndexSlot>;

/// Deterministic, thread-safe registry of per-signature ANN indexes.
///
/// One sharded index per [`MapKey`]: every item stored in an index was
/// embedded by that key's projection map, so distances are comparable.
/// Indexes are created lazily on the first index op for a signature; the
/// LSH backend's hyperplanes are seeded from `(master_seed, key)` so a
/// restarted coordinator reproduces identical bucket assignments. Every
/// shard of one signature shares that seed — per-shard hyperplanes would
/// make LSH candidate sets (and therefore recall) depend on the shard
/// count, breaking the bit-identity gate (`index::sharded` module docs).
pub struct IndexRegistry {
    master_seed: u64,
    backend: BackendKind,
    lsh: LshConfig,
    /// Directory index snapshots are written to / reloaded from (`None`
    /// disables the `snapshot`/`restore` wire ops and periodic
    /// snapshots).
    snapshot_dir: Option<PathBuf>,
    /// Rotation depth: how many snapshot sequences to keep per signature
    /// (oldest pruned after each successful write; minimum 1).
    snapshot_keep: usize,
    /// Shards per signature (minimum 1 = unsharded).
    shards: usize,
    /// Write-ahead log configuration (`None` disables logging; requires
    /// `snapshot_dir`, since checkpoints are snapshot cuts).
    wal: Option<WalConfig>,
    indexes: Mutex<HashMap<MapKey, SharedIndex>>,
}

/// Default rotation depth: the latest snapshot plus one predecessor, so a
/// snapshot that lands torn or wrong still leaves a recovery point.
pub const DEFAULT_SNAPSHOT_KEEP: usize = 2;

/// Default shard count: unsharded (one lane per signature).
pub const DEFAULT_INDEX_SHARDS: usize = 1;

/// Snapshot file-name stem of a signature: a salted key hash, stable
/// across master seeds and processes so `--restore` finds files by
/// content. A snapshot sequence `<seq>` consists of per-shard files
/// `<stem>.<seq>.shard<j>.snap` plus the checksummed root
/// `<stem>.<seq>.manifest` (written last — a sequence without a readable
/// manifest is never restored). Legacy pre-shard files `<stem>.<seq>.snap`
/// and unsequenced `<stem>.snap` (reads as sequence 0) restore by
/// re-partitioning their pairs into the configured shard count.
pub fn snapshot_file_stem(key: &MapKey) -> String {
    format!("sig_{:016x}", map_key_seed(0x5EED_F11E, key))
}

/// What role a snapshot-directory file plays in a sequence.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SnapKind {
    /// Pre-shard single-file snapshot (`<stem>[.<seq>].snap`).
    Legacy,
    /// One shard's file of a sharded sequence
    /// (`<stem>.<seq>.shard<j>.snap`).
    Shard,
    /// Sharded sequence root (`<stem>.<seq>.manifest`).
    Manifest,
}

/// Split a snapshot-directory file name into `(stem, sequence, kind)`;
/// `None` for names that belong to no snapshot layout.
fn parse_snapshot_name(name: &str) -> Option<(String, u64, SnapKind)> {
    if let Some(rest) = name.strip_suffix(".manifest") {
        let (stem, seq) = rest.rsplit_once('.')?;
        let seq = seq.parse::<u64>().ok()?;
        return Some((stem.to_string(), seq, SnapKind::Manifest));
    }
    let rest = name.strip_suffix(".snap")?;
    if let Some((front, last)) = rest.rsplit_once('.') {
        if last.strip_prefix("shard").is_some_and(|j| j.parse::<usize>().is_ok()) {
            let (stem, seq) = front.rsplit_once('.')?;
            let seq = seq.parse::<u64>().ok()?;
            return Some((stem.to_string(), seq, SnapKind::Shard));
        }
        if let Ok(seq) = last.parse::<u64>() {
            return Some((front.to_string(), seq, SnapKind::Legacy));
        }
    }
    Some((rest.to_string(), 0, SnapKind::Legacy))
}

/// The files of one snapshot sequence.
#[derive(Debug, Default)]
struct SeqFiles {
    manifest: Option<PathBuf>,
    shards: Vec<PathBuf>,
    legacy: Option<PathBuf>,
}

impl SeqFiles {
    /// A sequence restores iff its root exists: the manifest (sharded) or
    /// the legacy single file. Orphan shard files — a crash between shard
    /// writes and the manifest rename — are never restored from.
    fn restorable(&self) -> bool {
        self.manifest.is_some() || self.legacy.is_some()
    }
}

/// All snapshot sequences of one signature in `dir`, ascending. IO errors
/// propagate: treating an unreadable directory as "no snapshots" would
/// restart the rotation sequence below existing files (so a later restore
/// would silently load a stale higher sequence).
fn list_sequences(dir: &Path, stem: &str) -> std::result::Result<Vec<(u64, SeqFiles)>, String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
    let mut map: BTreeMap<u64, SeqFiles> = BTreeMap::new();
    for entry in rd {
        let p = entry.map_err(|e| format!("read {}: {e}", dir.display()))?.path();
        let name = match p.file_name().and_then(|s| s.to_str()) {
            Some(n) => n.to_string(),
            None => continue,
        };
        if let Some((s, seq, kind)) = parse_snapshot_name(&name) {
            if s != stem {
                continue;
            }
            let e = map.entry(seq).or_default();
            match kind {
                SnapKind::Manifest => e.manifest = Some(p),
                SnapKind::Shard => e.shards.push(p),
                SnapKind::Legacy => e.legacy = Some(p),
            }
        }
    }
    Ok(map.into_iter().collect())
}

/// A decoded snapshot source — a sharded manifest sequence or a legacy
/// single file — flattened to signature level so it can re-partition into
/// any shard count (answers are shard-count invariant).
struct SnapshotSource {
    key: MapKey,
    backend: BackendKind,
    lsh: LshConfig,
    seed: u64,
    dim: usize,
    inserts: u64,
    deletes: u64,
    queries: u64,
    items: Vec<(u64, Vec<f64>)>,
    /// Per-lane WAL watermarks this capture covers (empty for legacy or
    /// WAL-less sequences).
    wal_marks: Vec<u64>,
}

/// Read the newest restorable sequence of `stem` in `dir`. Manifest
/// sequences verify every shard file against the manifest's whole-file
/// checksum and item count before trusting it; a corrupt member fails the
/// read loudly (older sequences stay on disk for manual recovery).
fn read_snapshot_source(dir: &Path, stem: &str) -> std::result::Result<SnapshotSource, String> {
    let seqs = list_sequences(dir, stem)?;
    let (_, files) = seqs
        .into_iter()
        .rev()
        .find(|(_, f)| f.restorable())
        .ok_or_else(|| format!("no snapshot for this signature in {}", dir.display()))?;
    if let Some(mpath) = files.manifest {
        let manifest =
            ShardManifest::read(&mpath).map_err(|e| format!("{}: {e}", mpath.display()))?;
        let key = MapKey::decode(&manifest.key_bytes)
            .map_err(|e| format!("{}: {e}", mpath.display()))?;
        let mut snaps: Vec<IndexSnapshot> = Vec::with_capacity(manifest.shards.len());
        for entry in &manifest.shards {
            let spath = dir.join(&entry.file);
            let bytes = std::fs::read(&spath)
                .map_err(|e| format!("read {}: {e}", spath.display()))?;
            if persist::fnv1a(&bytes) != entry.checksum {
                return Err(format!(
                    "{}: shard file checksum disagrees with the manifest",
                    spath.display()
                ));
            }
            let snap = IndexSnapshot::decode(&bytes)
                .map_err(|e| format!("{}: {e}", spath.display()))?;
            if snap.key_bytes != manifest.key_bytes {
                return Err(format!(
                    "{}: shard file belongs to another signature",
                    spath.display()
                ));
            }
            if snap.items.len() as u64 != entry.items {
                return Err(format!(
                    "{}: item count disagrees with the manifest",
                    spath.display()
                ));
            }
            snaps.push(snap);
        }
        let (backend, lsh, seed, dim) =
            (snaps[0].backend, snaps[0].lsh, snaps[0].seed, snaps[0].dim);
        let mut inserts = 0u64;
        let mut deletes = 0u64;
        let mut queries = 0u64;
        let mut items = Vec::with_capacity(snaps.iter().map(|s| s.items.len()).sum());
        for snap in snaps {
            if (snap.backend, snap.dim) != (backend, dim) {
                return Err(format!(
                    "{}: shard files disagree on backend identity",
                    mpath.display()
                ));
            }
            // Mutation counters sum across shards; the query counter takes
            // the max (every query scattered to every shard, so each
            // shard's counter already equals the signature total).
            inserts += snap.inserts;
            deletes += snap.deletes;
            queries = queries.max(snap.queries);
            items.extend(snap.items);
        }
        Ok(SnapshotSource {
            key,
            backend,
            lsh,
            seed,
            dim,
            inserts,
            deletes,
            queries,
            items,
            wal_marks: manifest.wal_marks,
        })
    } else {
        let Some(path) = files.legacy else {
            return Err("restorable sequence lost its root mid-read".into());
        };
        let snap = IndexSnapshot::read(&path).map_err(|e| format!("{}: {e}", path.display()))?;
        let key = MapKey::decode(&snap.key_bytes).map_err(|e| format!("{}: {e}", path.display()))?;
        Ok(SnapshotSource {
            key,
            backend: snap.backend,
            lsh: snap.lsh,
            seed: snap.seed,
            dim: snap.dim,
            inserts: snap.inserts,
            deletes: snap.deletes,
            queries: snap.queries,
            items: snap.items,
            wal_marks: Vec::new(),
        })
    }
}

/// Re-partition a snapshot source into `shards` fresh backend shards (the
/// legacy-migration path when the source was unsharded or sharded
/// differently): every pair routes by [`shard_of`], counters restore
/// through the shared re-attribution rule
/// ([`crate::index::restore_shard_counters`]).
fn build_shards(src: SnapshotSource, shards: usize) -> Vec<Box<dyn AnnIndex>> {
    let mut out: Vec<Box<dyn AnnIndex>> = (0..shards)
        .map(|_| build_index(src.backend, src.dim, &src.lsh, src.seed))
        .collect();
    for (id, v) in &src.items {
        out[shard_of(*id, shards)].insert(*id, v);
    }
    crate::index::restore_shard_counters(&mut out, src.inserts, src.deletes, src.queries);
    out
}

/// Pre-built replacement shards for an in-turn restore: resolved off-turn
/// (file reads, checksum verification, re-partition, rebuild) so each
/// lane is held only for the pointer swap.
pub struct RestorePlan {
    /// Replacement index per shard, taken during that lane's pass.
    pub shards: Vec<Option<Box<dyn AnnIndex>>>,
    /// Total live items restored.
    pub items: u64,
}

impl IndexRegistry {
    /// New registry creating `backend` indexes (LSH shape from `lsh`),
    /// unsharded by default ([`IndexRegistry::with_shards`]).
    pub fn new(master_seed: u64, backend: BackendKind, lsh: LshConfig) -> Self {
        Self {
            master_seed,
            backend,
            lsh,
            snapshot_dir: None,
            snapshot_keep: DEFAULT_SNAPSHOT_KEEP,
            shards: DEFAULT_INDEX_SHARDS,
            wal: None,
            indexes: Mutex::new(HashMap::new()),
        }
    }

    /// Set the snapshot directory (builder-style).
    pub fn with_snapshot_dir(mut self, dir: Option<PathBuf>) -> Self {
        self.snapshot_dir = dir;
        self
    }

    /// Set the per-signature rotation depth (builder-style; clamped to
    /// ≥ 1 — "keep zero snapshots" would delete the sequence just
    /// written).
    pub fn with_snapshot_keep(mut self, keep: usize) -> Self {
        self.snapshot_keep = keep.max(1);
        self
    }

    /// Set the per-signature shard count (builder-style; clamped to ≥ 1).
    pub fn with_shards(mut self, shards: usize) -> Self {
        self.shards = shards.max(1);
        self
    }

    /// Enable the write-ahead log (builder-style). Callers must also
    /// configure a snapshot directory — checkpoints are snapshot cuts,
    /// and a WAL that can never truncate grows without bound.
    pub fn with_wal(mut self, wal: Option<WalConfig>) -> Self {
        self.wal = wal;
        self
    }

    /// The configured WAL, when any.
    pub fn wal_config(&self) -> Option<&WalConfig> {
        self.wal.as_ref()
    }

    /// The configured snapshot directory, when any.
    pub fn snapshot_dir(&self) -> Option<&Path> {
        self.snapshot_dir.as_deref()
    }

    /// The configured per-signature shard count.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Get or lazily create the index slot for `key` (dimension `key.k`).
    pub fn get_or_create(&self, key: &MapKey) -> SharedIndex {
        let mut indexes = lock_recover(&self.indexes);
        if let Some(slot) = indexes.get(key) {
            return Arc::clone(slot);
        }
        // Perturb the master so the hyperplane stream differs from the
        // projection map drawn for the same key. Every shard gets the
        // SAME seed — shard-invariant LSH codes are what make sharded
        // answers bit-identical to unsharded ones (struct docs).
        let seed = map_key_seed(self.master_seed ^ 0xA11_1DE8_5EED, key);
        let backends: Vec<Box<dyn AnnIndex>> = (0..self.shards)
            .map(|_| build_index(self.backend, key.k, &self.lsh, seed))
            .collect();
        // Fresh WAL lanes start above any checkpoint watermark already on
        // disk for this signature, so records logged from here on can
        // never be mistaken for already-covered ones by a later recovery.
        let start = self.newest_checkpoint_mark(key) + 1;
        let slot =
            Arc::new(IndexSlot::new_with_wal(key.clone(), backends, self.make_wal_writers(key, start)));
        indexes.insert(key.clone(), Arc::clone(&slot));
        slot
    }

    /// One freshly opened WAL writer per lane for `key` (`None` when the
    /// WAL is off). Open failures become `Err` sentinels — the lane
    /// serves error replies for mutations instead of panicking.
    fn make_wal_writers(
        &self,
        key: &MapKey,
        fresh_start_seq: u64,
    ) -> Option<Vec<std::result::Result<WalWriter, String>>> {
        let cfg = self.wal.as_ref()?;
        let stem = snapshot_file_stem(key);
        Some(
            (0..self.shards)
                .map(|j| {
                    WalWriter::open(
                        &cfg.dir,
                        &stem,
                        j as u32,
                        key.encode(),
                        cfg.segment_cap,
                        fresh_start_seq,
                    )
                })
                .collect(),
        )
    }

    /// Highest WAL watermark recorded in `key`'s newest restorable
    /// snapshot manifest (0 when there is none, or on any read problem —
    /// best-effort by design: this only seeds fresh writers above stale
    /// marks, and the infallible `get_or_create` path cannot surface an
    /// error).
    fn newest_checkpoint_mark(&self, key: &MapKey) -> u64 {
        if self.wal.is_none() {
            return 0;
        }
        let Some(dir) = self.snapshot_dir.as_ref() else { return 0 };
        let stem = snapshot_file_stem(key);
        let Ok(seqs) = list_sequences(dir, &stem) else { return 0 };
        let Some(mpath) = seqs.into_iter().rev().find_map(|(_, f)| f.manifest) else { return 0 };
        match ShardManifest::read(&mpath) {
            Ok(m) => m.wal_marks.into_iter().max().unwrap_or(0),
            Err(_) => 0,
        }
    }

    /// Every live slot (for current-value gauges: the metrics snapshot
    /// samples skew and active passes across all signatures).
    pub fn all_slots(&self) -> Vec<SharedIndex> {
        // lint:allow(unordered-iteration): feeds order-insensitive gauge
        // reductions (max skew, active-pass sums), never reply ordering.
        lock_recover(&self.indexes).values().map(Arc::clone).collect()
    }

    /// Write one snapshot sequence from per-shard captures (one
    /// [`IndexSnapshot`] per shard, in shard order): each shard file is
    /// written atomically, then the checksummed manifest (the sequence
    /// root) last, then sequences beyond the rotation depth are pruned —
    /// only after the manifest rename succeeded, so a failed write never
    /// costs an existing recovery point.
    ///
    /// The captures are frozen views (copy-on-write capture): the
    /// coordinator copies each shard's live pairs inside that lane's
    /// sequencer turn and calls this *off-turn*, so encoding and disk IO
    /// of a big corpus never stall the signature's lanes.
    pub fn write_snapshot(
        &self,
        slot: &IndexSlot,
        captures: &[IndexSnapshot],
    ) -> std::result::Result<SnapshotReport, String> {
        self.write_snapshot_with_marks(slot, captures, &[])
    }

    /// [`IndexRegistry::write_snapshot`] with the per-lane WAL watermarks
    /// the captures cover. The marks MUST be read at capture time (inside
    /// each lane's turn, or under its index lock) — recording a later seq
    /// would let recovery skip ops the snapshot never saw. WAL-enabled
    /// callers must use this form; empty marks in a WAL-enabled manifest
    /// would make recovery replay (double-apply) the whole log.
    pub fn write_snapshot_with_marks(
        &self,
        slot: &IndexSlot,
        captures: &[IndexSnapshot],
        wal_marks: &[u64],
    ) -> std::result::Result<SnapshotReport, String> {
        // Serialize with this signature's other off-turn snapshot IO —
        // concurrent writers would claim the same sequence number.
        let _io = lock_recover(&slot.snapshot_io);
        self.write_sequence(&slot.key, captures, wal_marks)
    }

    /// Write one snapshot sequence (shard files, then the manifest root,
    /// then rotation pruning) with no slot locking — the startup WAL
    /// recovery writes its checkpoint through here before any slot
    /// exists; concurrent callers must hold the slot's `snapshot_io`.
    fn write_sequence(
        &self,
        key: &MapKey,
        captures: &[IndexSnapshot],
        wal_marks: &[u64],
    ) -> std::result::Result<SnapshotReport, String> {
        let dir = self.snapshot_dir.as_ref().ok_or("no snapshot_dir configured")?;
        if captures.is_empty() {
            return Err("snapshot write needs at least one shard capture".into());
        }
        std::fs::create_dir_all(dir).map_err(|e| format!("create {}: {e}", dir.display()))?;
        let stem = snapshot_file_stem(key);
        let seq = list_sequences(dir, &stem)?.last().map(|(s, _)| s + 1).unwrap_or(1);
        let mut entries = Vec::with_capacity(captures.len());
        let mut items_total = 0u64;
        let mut bytes_total = 0u64;
        for (j, snap) in captures.iter().enumerate() {
            let name = format!("{stem}.{seq:08}.shard{j}.snap");
            let bytes = snap.encode();
            persist::write_bytes_atomic(&dir.join(&name), &bytes)?;
            items_total += snap.items.len() as u64;
            bytes_total += bytes.len() as u64;
            entries.push(ManifestShard {
                file: name,
                items: snap.items.len() as u64,
                checksum: persist::fnv1a(&bytes),
            });
        }
        let manifest = ShardManifest {
            key_bytes: key.encode(),
            shards: entries,
            wal_marks: wal_marks.to_vec(),
        };
        let mpath = dir.join(format!("{stem}.{seq:08}.manifest"));
        bytes_total += manifest.write_atomic(&mpath)?;
        // Prune beyond the rotation depth. Orphan sequences (shard files
        // without a manifest — a crashed write) older than the kept
        // window are swept too; they were never restorable.
        let seqs = list_sequences(dir, &stem)?;
        let restorable = seqs.iter().filter(|(_, f)| f.restorable()).count();
        let mut to_drop = restorable.saturating_sub(self.snapshot_keep);
        for (s, files) in seqs {
            if to_drop == 0 || s >= seq {
                break;
            }
            let was_restorable = files.restorable();
            if let Some(m) = files.manifest {
                let _ = std::fs::remove_file(m);
            }
            for p in files.shards {
                let _ = std::fs::remove_file(p);
            }
            if let Some(l) = files.legacy {
                let _ = std::fs::remove_file(l);
            }
            if was_restorable {
                to_drop -= 1;
            }
        }
        Ok(SnapshotReport {
            path: mpath.display().to_string(),
            items: items_total,
            bytes: bytes_total,
        })
    }

    /// Out-of-band snapshot of a slot (tests, tooling): captures each
    /// shard under its lock in ascending shard order, then writes the
    /// sequence. Unlike the coordinator's flushes — which capture inside
    /// each lane's sequencer turn at one arrival position — this cut is
    /// only per-shard consistent; call it on a quiescent slot when a
    /// signature-wide arrival-order cut matters. Mutation watermarks are
    /// recorded per shard at capture time and covered only on success,
    /// so concurrent traffic is never silently marked as durable.
    pub fn snapshot_slot(&self, slot: &IndexSlot) -> std::result::Result<SnapshotReport, String> {
        if self.snapshot_dir.is_none() {
            return Err("no snapshot_dir configured".into());
        }
        let logged = slot.wal_enabled();
        let mut captures = Vec::with_capacity(slot.shards());
        let mut marks = Vec::with_capacity(slot.shards());
        let mut wal_marks = Vec::with_capacity(if logged { slot.shards() } else { 0 });
        for s in 0..slot.shards() {
            let guard = slot.lock_shard(s);
            captures.push(IndexSnapshot::capture(slot.key.encode(), guard.as_ref()));
            // Read under the index lock: mutation noting (and WAL
            // appending) happens while that lock is held, so the
            // watermarks match the capture.
            marks.push((s, slot.shard_noted(s)));
            if logged {
                wal_marks.push(slot.wal_seq(s));
            }
        }
        let report = self.write_snapshot_with_marks(slot, &captures, &wal_marks)?;
        for (s, w) in marks {
            slot.cover_shard(s, w);
        }
        // The manifest rename is durable; covered segments may go now.
        for (s, &m) in wal_marks.iter().enumerate() {
            slot.wal_cover(s, m)?;
        }
        Ok(report)
    }

    /// Build the replacement shards for restoring `slot` from its newest
    /// snapshot sequence — file reads, checksum verification and the
    /// re-partition all happen here, off-turn, so lanes are later held
    /// only for the pointer swap. Works for both sharded sequences and
    /// legacy single-file snapshots (pairs re-partition by [`shard_of`]
    /// into the slot's shard count).
    pub fn restore_plan(&self, slot: &IndexSlot) -> std::result::Result<RestorePlan, String> {
        let dir = self.snapshot_dir.as_ref().ok_or("no snapshot_dir configured")?;
        let stem = snapshot_file_stem(&slot.key);
        // Serialize with in-flight snapshot writes so rotation can never
        // prune a sequence out from under this read. Note the weaker
        // cross-op ordering this buys: a snapshot's files land *after*
        // its lanes release, so a restore pipelined behind a snapshot
        // without awaiting its reply may still resolve the previous
        // sequence — the snapshot reply (sent only after the manifest
        // rename) is the read-your-writes barrier clients should await.
        let src = {
            let _io = lock_recover(&slot.snapshot_io);
            read_snapshot_source(dir, &stem)?
        };
        if src.key != slot.key {
            return Err("snapshot belongs to another signature".into());
        }
        // A wrong-dimension index would panic on the next insert — inside
        // a held sequencer turn, wedging the signature's lanes. Reject.
        if src.dim != slot.key.k {
            return Err(format!(
                "snapshot dim {} != signature k {}",
                src.dim, slot.key.k
            ));
        }
        let items = src.items.len() as u64;
        let shards = build_shards(src, slot.shards());
        Ok(RestorePlan { shards: shards.into_iter().map(Some).collect(), items })
    }

    /// Out-of-band restore of a slot (tests, tooling): builds the plan,
    /// swaps every shard under its lock, covering each shard's mutation
    /// watermark at its swap position (the reload discards everything
    /// applied so far). Returns the restored item count.
    pub fn restore_slot(&self, slot: &IndexSlot) -> std::result::Result<u64, String> {
        let plan = self.restore_plan(slot)?;
        for (s, replacement) in plan.shards.into_iter().enumerate() {
            let Some(replacement) = replacement else {
                return Err(format!("restore plan missing shard {s}"));
            };
            let len = replacement.len() as u64;
            let mut guard = lock_recover(&slot.lanes[s].index);
            *guard = replacement;
            slot.lanes[s].len.store(len, Ordering::Relaxed);
            slot.cover_shard(s, slot.shard_noted(s));
            // The logged tail predates the restored snapshot: replaying
            // it over the rewound state would resurrect discarded ops.
            slot.wal_reset(s)?;
            drop(guard);
        }
        Ok(plan.items)
    }

    /// Load the **newest** restorable sequence of every signature in
    /// `dir` into the registry (crash recovery at startup, before
    /// traffic), re-partitioning each into the configured shard count. A
    /// corrupt or foreign newest sequence fails the whole restore — a
    /// half-recovered corpus silently serving wrong results is worse than
    /// a loud startup error (older sequences stay on disk for manual
    /// recovery). Returns `(signatures, total items)` restored.
    pub fn restore_all(&self, dir: &Path) -> std::result::Result<(usize, u64), String> {
        let rd = std::fs::read_dir(dir).map_err(|e| format!("read {}: {e}", dir.display()))?;
        // Signatures are stems with at least one sequence root (manifest
        // or legacy file); bare shard files never restore. BTreeSet makes
        // the load order deterministic.
        let mut stems: BTreeSet<String> = BTreeSet::new();
        for entry in rd {
            let p = entry.map_err(|e| format!("read {}: {e}", dir.display()))?.path();
            let name = match p.file_name().and_then(|s| s.to_str()) {
                Some(n) => n.to_string(),
                None => continue,
            };
            if let Some((stem, _, kind)) = parse_snapshot_name(&name) {
                if matches!(kind, SnapKind::Manifest | SnapKind::Legacy) {
                    stems.insert(stem);
                }
            }
        }
        let mut indexes = lock_recover(&self.indexes);
        let mut items = 0u64;
        let mut count = 0usize;
        for stem in stems {
            let src = read_snapshot_source(dir, &stem).map_err(|e| format!("{stem}: {e}"))?;
            if src.dim != src.key.k {
                return Err(format!(
                    "{stem}: snapshot dim {} != signature k {}",
                    src.dim, src.key.k
                ));
            }
            let key = src.key.clone();
            // WAL recovery already rebuilt this signature as snapshot +
            // replayed tail — strictly newer than the snapshot alone, so
            // a snapshot-only reload here would silently roll it back.
            if self.wal.is_some() && indexes.contains_key(&key) {
                continue;
            }
            count += 1;
            items += src.items.len() as u64;
            // Fresh WAL lanes start above the restored checkpoint's own
            // watermarks, so post-restore appends stay unambiguously
            // newer than what this snapshot covers.
            let start = src.wal_marks.iter().copied().max().unwrap_or(0) + 1;
            let wals = self.make_wal_writers(&key, start);
            let shards = build_shards(src, self.shards);
            indexes.insert(key.clone(), Arc::new(IndexSlot::new_with_wal(key, shards, wals)));
        }
        Ok((count, items))
    }

    /// Startup crash recovery for the write-ahead log: for every
    /// signature with WAL segments on disk, rebuild its index as
    /// *newest restorable snapshot + replay of the logged tail*, then
    /// checkpoint the recovered state and restart the log. Signatures
    /// with snapshots but no WAL files load from their snapshots, so
    /// recovery is self-contained (not gated on a restore flag). No-op
    /// when the WAL is off. Returns `(signatures loaded, records
    /// replayed)`.
    ///
    /// Must run before serving, single-threaded. Crash-safe at every
    /// step: the recovered state is checkpointed with watermarks `[M]`
    /// (`M` = highest seq any surviving record or old mark reaches)
    /// *before* old segments are deleted, and fresh lanes start at
    /// `M + 1` — so a crash mid-cleanup leaves only records a rerun
    /// provably skips, and new appends can never collide with covered
    /// seqs. Lane-count changes are safe for the same reason: a lane
    /// index beyond the recorded marks falls back to `M`.
    pub fn recover_wal(&self) -> std::result::Result<(usize, u64), String> {
        let Some(cfg) = self.wal.clone() else { return Ok((0, 0)) };
        let snap_dir = self
            .snapshot_dir
            .clone()
            .ok_or("wal requires a snapshot_dir (checkpoints are snapshot cuts)")?;
        std::fs::create_dir_all(&cfg.dir)
            .map_err(|e| format!("create {}: {e}", cfg.dir.display()))?;
        std::fs::create_dir_all(&snap_dir)
            .map_err(|e| format!("create {}: {e}", snap_dir.display()))?;
        let mut sigs = 0usize;
        let mut replayed_total = 0u64;
        for (stem, lanes) in wal::scan_dir(&cfg.dir)? {
            // Read every lane's stream (BTreeMap: ascending shard id).
            // `None` lanes (only a torn-header file) carry no records.
            let mut streams: Vec<wal::LaneStream> = Vec::new();
            let mut wal_key: Option<Vec<u8>> = None;
            for (shard, files) in &lanes {
                let Some(stream) = wal::read_lane(files).map_err(|e| format!("{stem}: {e}"))?
                else {
                    continue;
                };
                if stream.shard != *shard {
                    return Err(format!(
                        "{stem}: shard{shard} header names shard {}",
                        stream.shard
                    ));
                }
                match &wal_key {
                    Some(k) if *k != stream.key_bytes => {
                        return Err(format!("{stem}: lanes disagree on the signature encoding"));
                    }
                    Some(_) => {}
                    None => wal_key = Some(stream.key_bytes.clone()),
                }
                streams.push(stream);
            }
            // Newest restorable snapshot of this signature, if any.
            let has_snapshot = list_sequences(&snap_dir, &stem)
                .map_err(|e| format!("{stem}: {e}"))?
                .iter()
                .any(|(_, f)| f.restorable());
            let src = if has_snapshot {
                Some(read_snapshot_source(&snap_dir, &stem).map_err(|e| format!("{stem}: {e}"))?)
            } else {
                None
            };
            // Resolve the signature; snapshot and WAL headers must agree.
            let key = match (&src, &wal_key) {
                (Some(s), Some(kb)) => {
                    if s.key.encode() != *kb {
                        return Err(format!(
                            "{stem}: wal lanes belong to a different signature than the snapshot"
                        ));
                    }
                    s.key.clone()
                }
                (Some(s), None) => s.key.clone(),
                (None, Some(kb)) => MapKey::decode(kb).map_err(|e| format!("{stem}: {e}"))?,
                // Only torn-header files and no snapshot: no state exists.
                (None, None) => continue,
            };
            let marks = src.as_ref().map(|s| s.wal_marks.clone()).unwrap_or_default();
            let max_mark = marks.iter().copied().max().unwrap_or(0);
            let mut shards: Vec<Box<dyn AnnIndex>> = match src {
                Some(src) => {
                    if src.dim != src.key.k {
                        return Err(format!(
                            "{stem}: snapshot dim {} != signature k {}",
                            src.dim, src.key.k
                        ));
                    }
                    build_shards(src, self.shards)
                }
                None => {
                    // WAL-only recovery (crash before the first
                    // checkpoint): start empty, exactly as
                    // `get_or_create` would have built this signature.
                    let seed = map_key_seed(self.master_seed ^ 0xA11_1DE8_5EED, &key);
                    (0..self.shards)
                        .map(|_| build_index(self.backend, key.k, &self.lsh, seed))
                        .collect()
                }
            };
            // Replay each lane's tail above its covered watermark. A lane
            // beyond the recorded marks (lane-count drift from a crashed
            // recovery or a shard-count change) falls back to `max_mark`:
            // such files survive only from a cleanup crash *after* a
            // checkpoint at `M ≥` all their seqs, so skipping is exact.
            let mut high = max_mark;
            let mut replayed = 0u64;
            for stream in &streams {
                let covered = marks.get(stream.shard as usize).copied().unwrap_or(max_mark);
                if let Some(last) = stream.records.last() {
                    high = high.max(last.seq);
                }
                for rec in &stream.records {
                    if rec.seq <= covered {
                        continue;
                    }
                    if rec.op == wal::WAL_OP_INSERT {
                        if rec.payload.len() != key.k {
                            return Err(format!(
                                "{stem}: wal insert {} carries dim {} (signature k {})",
                                rec.id,
                                rec.payload.len(),
                                key.k
                            ));
                        }
                        shards[shard_of(rec.id, self.shards)].insert(rec.id, &rec.payload);
                    } else {
                        shards[shard_of(rec.id, self.shards)].remove(rec.id);
                    }
                    replayed += 1;
                }
            }
            // Checkpoint the recovered state BEFORE touching the log:
            // once the manifest with marks `[high]` is durably renamed,
            // every surviving pre-recovery record is skippable, so a
            // crash anywhere in the cleanup below recovers to the same
            // state (never a double-apply).
            let captures: Vec<IndexSnapshot> =
                shards.iter().map(|s| IndexSnapshot::capture(key.encode(), s.as_ref())).collect();
            let cp_marks = vec![high; self.shards];
            self.write_sequence(&key, &captures, &cp_marks)
                .map_err(|e| format!("{stem}: {e}"))?;
            for files in lanes.values() {
                for (_, path) in files {
                    std::fs::remove_file(path)
                        .map_err(|e| format!("remove {}: {e}", path.display()))?;
                }
            }
            let wals: Vec<std::result::Result<WalWriter, String>> = (0..self.shards)
                .map(|j| {
                    WalWriter::open(
                        &cfg.dir,
                        &stem,
                        j as u32,
                        key.encode(),
                        cfg.segment_cap,
                        high + 1,
                    )
                })
                .collect();
            let slot = Arc::new(IndexSlot::new_with_wal(key.clone(), shards, Some(wals)));
            lock_recover(&self.indexes).insert(key, slot);
            sigs += 1;
            replayed_total += replayed;
        }
        // Signatures with snapshots but no WAL files (never mutated since
        // their lanes were truncated away, or a crash landed exactly
        // between recovery's checkpoint and its fresh segments) load from
        // their snapshots; `restore_all` skips everything handled above.
        let (snap_sigs, _items) = self.restore_all(&snap_dir)?;
        Ok((sigs + snap_sigs, replayed_total))
    }

    /// Number of live indexes.
    pub fn len(&self) -> usize {
        lock_recover(&self.indexes).len()
    }

    /// True when no index has been created yet.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{AnyTensor, TtTensor};

    fn tt_key() -> MapKey {
        MapKey { kind: MapKind::Tt { rank: 2 }, dims: vec![3; 4], k: 6 }
    }

    #[test]
    fn same_key_returns_same_map() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key()).unwrap();
        let b = reg.get_or_create(&tt_key()).unwrap();
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
    }

    #[test]
    fn same_master_seed_reproduces_identical_maps() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(42).get_or_create(&tt_key()).unwrap().map.project(&x);
        let y2 = ProjectionRegistry::new(42).get_or_create(&tt_key()).unwrap().map.project(&x);
        assert_eq!(y1, y2);
    }

    #[test]
    fn different_master_seed_differs() {
        let mut rng = Rng::seed_from(9);
        let x = AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng));
        let y1 = ProjectionRegistry::new(1).get_or_create(&tt_key()).unwrap().map.project(&x);
        let y2 = ProjectionRegistry::new(2).get_or_create(&tt_key()).unwrap().map.project(&x);
        assert_ne!(y1, y2);
    }

    #[test]
    fn different_keys_get_different_maps() {
        let reg = ProjectionRegistry::new(42);
        let a = reg.get_or_create(&tt_key()).unwrap();
        let mut k2 = tt_key();
        k2.k = 7;
        let b = reg.get_or_create(&k2).unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 2);
    }

    #[test]
    fn kinds_are_distinguished_in_seeding() {
        let reg = ProjectionRegistry::new(0);
        let tt = MapKey { kind: MapKind::Tt { rank: 3 }, dims: vec![4; 3], k: 5 };
        let cp = MapKey { kind: MapKind::Cp { rank: 3 }, dims: vec![4; 3], k: 5 };
        assert_ne!(reg.seed_for(&tt), reg.seed_for(&cp));
    }

    #[test]
    fn buffer_pool_recycles_allocations() {
        let pool = WorkspacePool::new();
        let buf = pool.acquire_buf(32);
        assert_eq!(buf.len(), 32);
        assert!(buf.iter().all(|&v| v == 0.0));
        pool.release_buf(buf);
        assert_eq!(pool.idle_bufs(), 1);
        // Reacquire at a different size: same allocation, new length, and
        // the contents are zeroed again.
        let mut buf = pool.acquire_buf(8);
        assert_eq!(pool.idle_bufs(), 0);
        assert_eq!(buf.len(), 8);
        buf[0] = 7.0;
        pool.release_buf(buf);
        let buf = pool.acquire_buf(8);
        assert!(buf.iter().all(|&v| v == 0.0), "recycled buffers are re-zeroed");
        pool.release_buf(buf);
        // A grossly oversized pooled buffer is not handed out for a tiny
        // request (its capacity would leave the process inside a reply).
        pool.release_buf(vec![0.0; 4096]);
        let tiny = pool.acquire_buf(4);
        assert!(tiny.capacity() < 4096, "flush-sized buffer must not back a tiny reply");
    }

    #[test]
    fn index_registry_returns_same_index_for_same_key() {
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        assert!(reg.is_empty());
        let a = reg.get_or_create(&tt_key());
        let b = reg.get_or_create(&tt_key());
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(reg.len(), 1);
        assert_eq!(a.shards(), 1, "default is unsharded");
        assert_eq!(a.lock_shard(0).dim(), tt_key().k);
    }

    #[test]
    fn index_slot_runs_lane_tickets_in_issue_order() {
        let reg = IndexRegistry::new(
            1,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        let slot = reg.get_or_create(&tt_key());
        let t0 = slot.issue_tickets(&[0]);
        let t1 = slot.issue_tickets(&[0]);
        assert_eq!((t0[0], t1[0]), ((0, 0), (0, 1)));
        let log = Arc::new(Mutex::new(Vec::new()));
        // Run the *later* ticket on another thread first: it must block
        // until the earlier ticket completes.
        let handle = {
            let slot = Arc::clone(&slot);
            let log = Arc::clone(&log);
            std::thread::spawn(move || {
                slot.run_shard_turn(0, 1, |_| log.lock().unwrap().push(1));
            })
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        slot.run_shard_turn(0, 0, |_| log.lock().unwrap().push(0));
        handle.join().unwrap();
        assert_eq!(*log.lock().unwrap(), vec![0, 1]);
    }

    #[test]
    fn shard_lanes_sequence_independently() {
        // A held turn on one lane must not stall another lane — that
        // independence is the whole point of sharding the slot.
        let reg = IndexRegistry::new(
            1,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_shards(2);
        let slot = reg.get_or_create(&tt_key());
        assert_eq!(slot.shards(), 2);
        // Hold lane 1's first turn open on another thread.
        let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
        let (entered_tx, entered_rx) = std::sync::mpsc::channel::<()>();
        let holder = {
            let slot = Arc::clone(&slot);
            std::thread::spawn(move || {
                slot.run_shard_turn(1, 0, |_| {
                    entered_tx.send(()).unwrap();
                    hold_rx.recv().unwrap();
                });
            })
        };
        entered_rx.recv().unwrap();
        // Lane 0 advances while lane 1 is held.
        let before = std::time::Instant::now();
        slot.run_shard_turn(0, 0, |index| index.insert(4, &vec![0.0; tt_key().k]));
        assert!(
            before.elapsed() < std::time::Duration::from_secs(2),
            "lane 0 must not wait for lane 1's held turn"
        );
        hold_tx.send(()).unwrap();
        holder.join().unwrap();
        // Both lanes saw exactly one pass; skew reflects the lone insert.
        assert_eq!(slot.shard_lens(), vec![1, 0]);
        assert_eq!(slot.max_skew(), 1);
        assert!(slot.parallel_high_water() >= 1);
    }

    #[test]
    fn barrier_tickets_cover_every_lane() {
        let reg = IndexRegistry::new(
            1,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_shards(3);
        let slot = reg.get_or_create(&tt_key());
        let tickets = slot.issue_barrier();
        assert_eq!(tickets, vec![(0, 0), (1, 0), (2, 0)]);
        let tickets = slot.issue_tickets(&[2]);
        assert_eq!(tickets, vec![(2, 1)], "lanes advance independently");
    }

    #[test]
    fn map_key_encoding_roundtrips() {
        let keys = [
            tt_key(),
            MapKey { kind: MapKind::Cp { rank: 7 }, dims: vec![2, 5, 2], k: 9 },
            MapKey { kind: MapKind::Gaussian, dims: vec![15; 3], k: 64 },
            MapKey { kind: MapKind::VerySparse, dims: vec![1 << 12], k: 32 },
        ];
        for key in keys {
            assert_eq!(MapKey::decode(&key.encode()).unwrap(), key);
        }
        assert!(MapKey::decode(&[]).is_err());
        assert!(MapKey::decode(&[9; 30]).is_err(), "garbage header rejected");
        let mut bytes = tt_key().encode();
        bytes[0] = 9;
        assert!(MapKey::decode(&bytes).is_err(), "unknown kind tag rejected");
        bytes[0] = 1;
        bytes.push(0);
        assert!(MapKey::decode(&bytes).is_err(), "trailing bytes rejected");
    }

    #[test]
    fn registry_snapshot_roundtrips_through_disk() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_snap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Lsh,
            crate::index::LshConfig { tables: 3, bits: 5, probes: 2 },
        )
        .with_snapshot_dir(Some(dir.clone()));
        let slot = reg.get_or_create(&tt_key());
        let mut rng = Rng::seed_from(4);
        let qs: Vec<Vec<f64>> = (0..4).map(|_| rng.gaussian_vec(tt_key().k, 1.0)).collect();
        {
            let mut index = slot.lock_shard(0);
            for i in 0..12u64 {
                index.insert(i, &rng.gaussian_vec(tt_key().k, 1.0));
            }
        }
        let report = reg.snapshot_slot(&slot).unwrap();
        assert_eq!(report.items, 12);
        assert!(report.bytes > 0);
        assert!(report.path.ends_with(".manifest"), "report points at the sequence root");
        // A fresh registry (same master seed), sharded 3-way, restores
        // bit-identically: the legacy-free migration path re-partitions.
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Lsh,
            crate::index::LshConfig { tables: 3, bits: 5, probes: 2 },
        )
        .with_shards(3);
        let (sigs, items) = reg2.restore_all(&dir).unwrap();
        assert_eq!((sigs, items), (1, 12));
        let slot2 = reg2.get_or_create(&tt_key());
        assert_eq!(slot2.shards(), 3);
        assert_eq!(slot2.shard_lens().iter().sum::<u64>(), 12);
        let mut ws = crate::projections::Workspace::new();
        for q in &qs {
            let want = slot.lock_shard(0).query(q, 3, &mut ws);
            // Scatter-gather over the restored shards must agree bitwise.
            let got = (0..3).fold(Vec::new(), |acc, s| {
                let res = slot2.lock_shard(s).query(q, 3, &mut ws);
                crate::index::merge_neighbors(acc, res, 3)
            });
            assert_eq!(got, want);
        }
        // Aggregated counters survive the re-partition.
        let total_inserts: u64 = (0..3).map(|s| slot2.lock_shard(s).stats().inserts).sum();
        assert_eq!(total_inserts, 12);
        // Without a snapshot_dir the ops fail loudly instead of writing
        // somewhere surprising.
        let slot3 = reg2.get_or_create(&tt_key());
        assert!(reg2.snapshot_slot(&slot3).is_err());
        assert!(reg2.restore_slot(&slot3).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sharded_snapshot_writes_manifest_plus_shard_files() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_shardsnap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = IndexRegistry::new(
            3,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()))
        .with_shards(4);
        let slot = reg.get_or_create(&tt_key());
        for i in 0..40u64 {
            let s = shard_of(i, 4);
            slot.lock_shard(s).insert(i, &vec![i as f64; tt_key().k]);
        }
        let report = reg.snapshot_slot(&slot).unwrap();
        assert_eq!(report.items, 40);
        let stem = snapshot_file_stem(&tt_key());
        let seqs = list_sequences(&dir, &stem).unwrap();
        assert_eq!(seqs.len(), 1);
        let (_, files) = &seqs[0];
        assert!(files.manifest.is_some());
        assert_eq!(files.shards.len(), 4, "one file per shard");
        assert!(files.legacy.is_none());
        // Restoring into a differently-sharded registry keeps every item.
        let reg2 = IndexRegistry::new(
            3,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_shards(2);
        assert_eq!(reg2.restore_all(&dir).unwrap(), (1, 40));
        let slot2 = reg2.get_or_create(&tt_key());
        let mut seen = Vec::new();
        for s in 0..2 {
            slot2.lock_shard(s).for_each_live(&mut |id, v| {
                assert_eq!(v, &vec![id as f64; tt_key().k][..]);
                seen.push(id);
            });
        }
        seen.sort_unstable();
        assert_eq!(seen, (0..40).collect::<Vec<u64>>());
        // A corrupted shard file fails the restore loudly.
        let shard_path = files.shards[0].clone();
        let mut bytes = std::fs::read(&shard_path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x20;
        std::fs::write(&shard_path, bytes).unwrap();
        let reg3 = IndexRegistry::new(
            3,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        assert!(reg3.restore_all(&dir).is_err(), "corrupt shard member must fail loudly");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn wal_recovery_replays_the_logged_tail_into_a_different_shard_count() {
        let base = std::env::temp_dir()
            .join(format!("trp_state_wal_{}", std::process::id()));
        let snap = base.join("snap");
        let wal_dir = base.join("wal");
        let _ = std::fs::remove_dir_all(&base);
        let wal_cfg = WalConfig {
            dir: wal_dir.clone(),
            segment_cap: 1 << 16,
            fsync: WalFsync::Flush,
        };
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(snap.clone()))
        .with_shards(2)
        .with_wal(Some(wal_cfg.clone()));
        std::fs::create_dir_all(&wal_dir).unwrap();
        let slot = reg.get_or_create(&tt_key());
        assert!(slot.wal_enabled());
        // Log + apply, exactly as a server turn does.
        for i in 0..10u64 {
            let v = vec![i as f64; tt_key().k];
            let s = shard_of(i, 2);
            slot.wal_append(s, wal::WAL_OP_INSERT, i, &v).unwrap();
            slot.lock_shard(s).insert(i, &v);
        }
        let s3 = shard_of(3, 2);
        slot.wal_append(s3, wal::WAL_OP_DELETE, 3, &[]).unwrap();
        slot.lock_shard(s3).remove(3);
        for s in 0..2 {
            slot.wal_commit(s, WalFsync::Flush).unwrap();
        }
        assert_eq!(slot.wal_lag(), 11, "nothing checkpointed yet");
        // "Crash": drop the registry with no snapshot ever taken, then
        // recover into a *different* shard count.
        drop(slot);
        drop(reg);
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(snap.clone()))
        .with_shards(3)
        .with_wal(Some(wal_cfg.clone()));
        assert_eq!(reg2.recover_wal().unwrap(), (1, 11));
        let slot2 = reg2.get_or_create(&tt_key());
        assert_eq!(slot2.shards(), 3);
        assert_eq!(slot2.wal_lag(), 0, "recovery checkpoints what it rebuilt");
        let mut seen = Vec::new();
        for s in 0..3 {
            slot2.lock_shard(s).for_each_live(&mut |id, v| {
                assert_eq!(v, &vec![id as f64; tt_key().k][..]);
                seen.push(id);
            });
        }
        seen.sort_unstable();
        let want: Vec<u64> = (0..10).filter(|&i| i != 3).collect();
        assert_eq!(seen, want, "replay applies the delete too");
        // Recovery is idempotent: a second pass finds the checkpoint it
        // wrote, replays nothing, and lands on the same state.
        drop(slot2);
        drop(reg2);
        let reg3 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(snap))
        .with_shards(3)
        .with_wal(Some(wal_cfg));
        assert_eq!(reg3.recover_wal().unwrap(), (1, 0));
        let slot3 = reg3.get_or_create(&tt_key());
        assert_eq!(slot3.shard_lens().iter().sum::<u64>(), 9);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn snapshot_checkpoint_records_marks_and_drains_the_lag() {
        let base = std::env::temp_dir()
            .join(format!("trp_state_walcp_{}", std::process::id()));
        let snap = base.join("snap");
        let wal_dir = base.join("wal");
        let _ = std::fs::remove_dir_all(&base);
        std::fs::create_dir_all(&wal_dir).unwrap();
        let wal_cfg = WalConfig {
            dir: wal_dir,
            segment_cap: 1 << 16,
            fsync: WalFsync::Flush,
        };
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(snap.clone()))
        .with_wal(Some(wal_cfg.clone()));
        let slot = reg.get_or_create(&tt_key());
        for i in 0..5u64 {
            let v = vec![i as f64; tt_key().k];
            slot.wal_append(0, wal::WAL_OP_INSERT, i, &v).unwrap();
            slot.lock_shard(0).insert(i, &v);
        }
        slot.wal_commit(0, WalFsync::Flush).unwrap();
        assert_eq!(slot.wal_lag(), 5);
        reg.snapshot_slot(&slot).unwrap();
        assert_eq!(slot.wal_lag(), 0, "the cut covers everything logged so far");
        let src = read_snapshot_source(&snap, &snapshot_file_stem(&tt_key())).unwrap();
        assert_eq!(src.wal_marks, vec![5], "manifest carries the lane watermark");
        // Post-checkpoint recovery replays nothing yet restores all items.
        drop(slot);
        drop(reg);
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(snap))
        .with_wal(Some(wal_cfg));
        assert_eq!(reg2.recover_wal().unwrap(), (1, 0));
        let slot2 = reg2.get_or_create(&tt_key());
        assert_eq!(slot2.shard_lens(), vec![5]);
        let _ = std::fs::remove_dir_all(&base);
    }

    #[test]
    fn concurrent_snapshot_writes_claim_distinct_sequences() {
        // Off-turn writes race-freely: the per-slot snapshot_io lock
        // serializes sequence-number selection, so concurrent writers can
        // never interleave renames into one corrupt sequence.
        let dir = std::env::temp_dir()
            .join(format!("trp_state_concsnap_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = Arc::new(
            IndexRegistry::new(
                7,
                crate::index::BackendKind::Flat,
                crate::index::LshConfig::default(),
            )
            .with_snapshot_dir(Some(dir.clone()))
            .with_snapshot_keep(8),
        );
        let slot = reg.get_or_create(&tt_key());
        slot.lock_shard(0).insert(1, &vec![1.0; tt_key().k]);
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let reg = Arc::clone(&reg);
                let slot = Arc::clone(&slot);
                std::thread::spawn(move || reg.snapshot_slot(&slot).unwrap())
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let seqs = list_sequences(&dir, &snapshot_file_stem(&tt_key())).unwrap();
        let kept: Vec<u64> = seqs.iter().map(|(s, _)| *s).collect();
        assert_eq!(kept, vec![1, 2, 3, 4], "each writer claims its own sequence");
        for (_, files) in &seqs {
            assert!(files.manifest.is_some(), "every sequence is manifest-rooted");
        }
        // The newest sequence restores cleanly.
        assert_eq!(reg.restore_slot(&slot).unwrap(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_rotation_keeps_last_n_and_restores_newest() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_rot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()))
        .with_snapshot_keep(2);
        let slot = reg.get_or_create(&tt_key());
        for round in 0..3u64 {
            slot.lock_shard(0).insert(round, &vec![round as f64; tt_key().k]);
            reg.snapshot_slot(&slot).unwrap();
        }
        // Three writes, rotation depth 2: the two newest sequences remain
        // (manifest + shard file each).
        let stem = snapshot_file_stem(&tt_key());
        let seqs = list_sequences(&dir, &stem).unwrap();
        let kept: Vec<u64> = seqs.iter().map(|(s, _)| *s).collect();
        assert_eq!(kept, vec![2, 3], "oldest sequence must be pruned");
        for (_, files) in &seqs {
            assert!(files.manifest.is_some());
            assert_eq!(files.shards.len(), 1);
        }
        // restore_slot reads the newest cut (all three items).
        slot.lock_shard(0).remove(0);
        let restored = reg.restore_slot(&slot).unwrap();
        assert_eq!(restored, 3);
        {
            let index = slot.lock_shard(0);
            assert_eq!(index.len(), 3);
            // Counters restored from the capture, not the rebuild.
            assert_eq!(index.stats().inserts, 3);
        }
        // Startup recovery also picks the newest sequence per signature.
        let reg2 = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        );
        assert_eq!(reg2.restore_all(&dir).unwrap(), (1, 3));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_unsequenced_snapshots_still_restore() {
        let dir = std::env::temp_dir()
            .join(format!("trp_state_legacy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()));
        let slot = reg.get_or_create(&tt_key());
        // Write a PR 3-era file: `<stem>.snap`, single file, no sequence.
        {
            let mut index = slot.lock_shard(0);
            index.insert(1, &vec![1.0; tt_key().k]);
            let snap = crate::index::IndexSnapshot::capture(slot.key.encode(), index.as_ref());
            let legacy = dir.join(format!("{}.snap", snapshot_file_stem(&tt_key())));
            snap.write_atomic(&legacy).unwrap();
            index.insert(2, &vec![2.0; tt_key().k]);
        }
        // The legacy file reads as sequence 0, so restore finds it…
        let restored = reg.restore_slot(&slot).unwrap();
        assert_eq!(restored, 1);
        // …and the next rotation write supersedes it with sequence 1.
        slot.lock_shard(0).insert(3, &vec![3.0; tt_key().k]);
        reg.snapshot_slot(&slot).unwrap();
        let restored = reg.restore_slot(&slot).unwrap();
        assert_eq!(restored, 2, "sequenced snapshot supersedes the legacy file");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_snapshot_repartitions_into_configured_shards() {
        // The migration path: a pre-shard single-file snapshot restores
        // into a sharded registry with every pair routed by the id hash.
        let dir = std::env::temp_dir()
            .join(format!("trp_state_migrate_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let mut legacy_index = crate::index::FlatIndex::new(tt_key().k);
        for i in 0..25u64 {
            legacy_index.insert(i, &vec![i as f64; tt_key().k]);
        }
        legacy_index.remove(7);
        let snap = crate::index::IndexSnapshot::capture(tt_key().encode(), &legacy_index);
        snap.write_atomic(&dir.join(format!("{}.snap", snapshot_file_stem(&tt_key()))))
            .unwrap();
        let reg = IndexRegistry::new(
            7,
            crate::index::BackendKind::Flat,
            crate::index::LshConfig::default(),
        )
        .with_snapshot_dir(Some(dir.clone()))
        .with_shards(4);
        let (sigs, items) = reg.restore_all(&dir).unwrap();
        assert_eq!((sigs, items), (1, 24));
        let slot = reg.get_or_create(&tt_key());
        assert_eq!(slot.shards(), 4);
        // Every pair landed on its hash shard; nothing was lost or moved.
        for s in 0..4 {
            slot.lock_shard(s).for_each_live(&mut |id, v| {
                assert_eq!(shard_of(id, 4), s, "pair routed to the wrong shard");
                assert_eq!(v, &vec![id as f64; tt_key().k][..]);
            });
        }
        let total: u64 = slot.shard_lens().iter().sum();
        assert_eq!(total, 24);
        // Aggregated counters reproduce the legacy totals.
        let inserts: u64 = (0..4).map(|s| slot.lock_shard(s).stats().inserts).sum();
        let deletes: u64 = (0..4).map(|s| slot.lock_shard(s).stats().deletes).sum();
        assert_eq!((inserts, deletes), (25, 1));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn snapshot_names_parse_every_layout() {
        assert_eq!(
            parse_snapshot_name("sig_ab.00000003.snap"),
            Some(("sig_ab".into(), 3, SnapKind::Legacy))
        );
        assert_eq!(
            parse_snapshot_name("sig_ab.snap"),
            Some(("sig_ab".into(), 0, SnapKind::Legacy))
        );
        assert_eq!(
            parse_snapshot_name("sig_ab.00000003.shard2.snap"),
            Some(("sig_ab".into(), 3, SnapKind::Shard))
        );
        assert_eq!(
            parse_snapshot_name("sig_ab.00000003.manifest"),
            Some(("sig_ab".into(), 3, SnapKind::Manifest))
        );
        assert_eq!(parse_snapshot_name("notes.txt"), None);
        // Shard files without a parsable sequence are ignored entirely
        // (they could otherwise masquerade as legacy roots and clobber a
        // signature's restore).
        assert_eq!(parse_snapshot_name("sig.shard2.snap"), None);
    }

    #[test]
    fn index_seed_differs_from_map_seed() {
        // The LSH hyperplane stream must not reuse the projection map's
        // stream for the same key.
        let key = tt_key();
        assert_ne!(map_key_seed(7, &key), map_key_seed(7 ^ 0xA11_1DE8_5EED, &key));
    }
}
