//! TCP front-end: newline-delimited JSON requests over plain sockets
//! (std::net — no async runtime offline, and the workload is compute-
//! bound so blocking I/O threads are the right tool).
//!
//! Exactly two threads per connection: a reader that decodes lines and
//! submits them to the coordinator, and one reply-writer draining an
//! mpsc channel of pending replies in request order. Pipelined requests
//! still overlap in the batcher (submission never waits on a reply);
//! only the response *writes* are serialized, which the single socket
//! forces anyway. The reader joins the writer on every exit path — EOF,
//! read error, or server shutdown — so no handle or thread accumulates
//! per request.

use super::request::ProjectRequest;
use super::server::{Coordinator, Reply};
use super::wire;
use crate::obs::{Span, TraceRecorder};
use crate::util::sync::lock_recover;
use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Mutex};

/// Socket handles of live connections, used to unblock their readers at
/// shutdown. Each handler removes its own entry on exit, so a finished
/// connection's duplicated fd is closed (and FIN sent) immediately, not
/// at the next accept.
type ConnStreams = Arc<Mutex<HashMap<u64, TcpStream>>>;
/// Join handles of connection reader threads (reaped on accept, joined
/// at shutdown). A finished handle holds no socket — only exit status.
type ConnHandles = Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>;

/// Handle to a running TCP server.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    served: Arc<AtomicU64>,
    conn_streams: ConnStreams,
    conn_handles: ConnHandles,
}

impl NetServer {
    /// Start serving `coordinator` on `addr` (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port). The coordinator must outlive the server; it is
    /// shared behind an `Arc`.
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let conn_streams: ConnStreams = Arc::new(Mutex::new(HashMap::new()));
        let conn_handles: ConnHandles = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            let conn_streams = Arc::clone(&conn_streams);
            let conn_handles = Arc::clone(&conn_handles);
            std::thread::spawn(move || {
                accept_loop(listener, coordinator, stop, served, conn_streams, conn_handles);
            })
        };
        Ok(NetServer {
            addr: local,
            stop,
            accept_thread: Some(accept_thread),
            served,
            conn_streams,
            conn_handles,
        })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop accepting, unblock every connection reader (half-close of the
    /// read side), and join all connection threads. Requests already read
    /// off a socket get their replies written before the connection
    /// closes.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
        // Readers block in `lines()`; shutting down the read side makes
        // that return EOF so the connection drains and exits.
        // lint:allow(unordered-iteration): every live socket gets the same
        // half-close; visit order cannot affect any reply.
        for stream in lock_recover(&self.conn_streams).values() {
            let _ = stream.shutdown(Shutdown::Read);
        }
        let handles: Vec<_> = std::mem::take(&mut *lock_recover(&self.conn_handles));
        for handle in handles {
            let _ = handle.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
    conn_streams: ConnStreams,
    conn_handles: ConnHandles,
) {
    let mut next_conn_id = 0u64;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Keep a socket handle for shutdown; a connection we
                // cannot later unblock is a connection we don't serve.
                let Ok(peer) = stream.try_clone() else {
                    continue;
                };
                let conn_id = next_conn_id;
                next_conn_id += 1;
                lock_recover(&conn_streams).insert(conn_id, peer);
                let coordinator = Arc::clone(&coordinator);
                let served = Arc::clone(&served);
                let streams = Arc::clone(&conn_streams);
                let handle = std::thread::spawn(move || {
                    let _ = handle_connection(stream, coordinator, served);
                    // Drop the registry's duplicated fd as soon as the
                    // connection ends, so the peer sees FIN now and an
                    // idle server holds no dead sockets.
                    lock_recover(&streams).remove(&conn_id);
                });
                let mut handles = lock_recover(&conn_handles);
                handles.retain(|h| !h.is_finished());
                handles.push(handle);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

/// One entry in the per-connection reply queue, in request order.
enum Outgoing {
    /// A submitted request: id + the channel its reply arrives on + the
    /// trace context its spans carry (client-supplied or assigned).
    Pending(u64, Receiver<Reply>, Option<u64>),
    /// An undecodable line: best-effort recovered id (None → `"id":
    /// null` on the wire) + the decode error.
    Malformed(Option<u64>, String),
}

fn handle_connection(
    stream: TcpStream,
    coordinator: Arc<Coordinator>,
    served: Arc<AtomicU64>,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    let write_half = stream.try_clone()?;
    let (tx, rx) = channel::<Outgoing>();
    let trace = coordinator.trace();
    let writer = {
        let trace = trace.clone();
        std::thread::spawn(move || reply_writer_loop(write_half, rx, served, trace))
    };
    let reader = BufReader::new(stream);
    let mut read_result = Ok(());
    for line in reader.lines() {
        let line = match line {
            Ok(line) => line,
            Err(e) => {
                // Fall through to the join below: pending replies still
                // get written before the connection is torn down.
                read_result = Err(e);
                break;
            }
        };
        if line.trim().is_empty() {
            continue;
        }
        let t0 = trace.as_ref().map(|t| t.now_us());
        let out = match wire::decode_request(&line) {
            Ok(req) => {
                let id = req.id;
                // Resolve the trace context up front so the recv span,
                // the coordinator's internal spans, and the write span
                // all share one id per request.
                let span_trace = coordinator.span_trace_for(&req);
                let pending = coordinator.submit_with_span(req, span_trace);
                // "recv" covers decode + submit (to batcher enqueue).
                if let (Some(t), Some(start)) = (trace.as_deref(), t0) {
                    t.record(Span {
                        stage: "recv",
                        req: Some(id),
                        trace: span_trace,
                        start_us: start,
                        dur_us: t.now_us().saturating_sub(start),
                        ..Span::default()
                    });
                }
                Outgoing::Pending(id, pending, span_trace)
            }
            Err(e) => Outgoing::Malformed(wire::parse_request_id(&line), e),
        };
        if tx.send(out).is_err() {
            break; // Writer exited (socket write failed): stop reading.
        }
    }
    drop(tx);
    let _ = writer.join();
    read_result
}

/// Drain the reply queue: wait for each pending reply in request order
/// and write it. Exits when the reader drops its sender (EOF, read
/// error, shutdown) and the queue is drained, or when a write fails.
fn reply_writer_loop(
    mut stream: TcpStream,
    rx: Receiver<Outgoing>,
    served: Arc<AtomicU64>,
    trace: Option<Arc<TraceRecorder>>,
) {
    for out in rx {
        let (id, result, span_trace) = match out {
            Outgoing::Pending(id, reply, span_trace) => {
                let result = reply
                    .recv()
                    .unwrap_or_else(|_| Err("coordinator dropped the request".into()));
                served.fetch_add(1, Ordering::Relaxed);
                (Some(id), result, span_trace)
            }
            Outgoing::Malformed(id, e) => (id, Err(e), None),
        };
        // "write" covers encode + socket write (not the reply wait).
        let t0 = trace.as_ref().map(|t| t.now_us());
        let line = wire::encode_response(&result, id);
        let wrote = writeln!(stream, "{line}").and_then(|()| stream.flush());
        if let (Some(t), Some(start)) = (trace.as_deref(), t0) {
            t.record(Span {
                stage: "write",
                req: id,
                trace: span_trace,
                start_us: start,
                dur_us: t.now_us().saturating_sub(start),
                ..Span::default()
            });
        }
        if wrote.is_err() {
            break; // Client gone; the reader notices via the closed channel.
        }
    }
}

/// Minimal blocking client for the wire protocol (used by tests, the
/// `trp client` subcommand and the serving example).
pub struct NetClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl NetClient {
    /// Connect to a server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient { writer: stream, reader })
    }

    /// Send one request (does not wait).
    pub fn send(&mut self, req: &ProjectRequest) -> std::io::Result<()> {
        writeln!(self.writer, "{}", wire::encode_request(req))?;
        self.writer.flush()
    }

    /// Read the next response line.
    pub fn recv(&mut self) -> std::io::Result<wire::WireResponse> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        wire::decode_response(line.trim_end())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Send and wait for the matching response (single in-flight).
    pub fn roundtrip(&mut self, req: &ProjectRequest) -> std::io::Result<wire::WireResponse> {
        self.send(req)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::rng::Rng;
    use crate::tensor::{AnyTensor, TtTensor};

    fn start_server() -> (Arc<Coordinator>, NetServer) {
        let coord = Arc::new(Coordinator::start(
            CoordinatorConfig { default_k: 8, workers: 2, ..Default::default() },
            None,
        ));
        let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        (coord, server)
    }

    #[test]
    fn tcp_roundtrip() {
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(1);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let resp = client
            .roundtrip(&ProjectRequest::new(5, AnyTensor::Tt(x)))
            .unwrap();
        assert_eq!(resp.id, Some(5));
        assert_eq!(resp.embedding.unwrap().len(), 8);
        assert!(resp.error.is_none());
        server.shutdown();
    }

    #[test]
    fn pipelined_requests_all_answered() {
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(2);
        let n = 16;
        for i in 0..n {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            client.send(&ProjectRequest::new(i, AnyTensor::Tt(x))).unwrap();
        }
        let mut ids: Vec<u64> = (0..n).map(|_| client.recv().unwrap().id.unwrap()).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<u64>>());
        server.shutdown();
    }

    #[test]
    fn malformed_line_yields_error_response() {
        let (_coord, server) = start_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        writeln!(w, "this is not json").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = wire::decode_response(line.trim_end()).unwrap();
        assert!(resp.error.is_some());
        server.shutdown();
    }

    #[test]
    fn malformed_line_reply_does_not_collide_with_live_id0_request() {
        let (_coord, server) = start_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        let mut rng = Rng::seed_from(9);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        // Pipeline a legitimate id-0 request, then garbage, then a valid
        // JSON request with an unknown op (its id is recoverable).
        writeln!(w, "{}", wire::encode_request(&ProjectRequest::new(0, AnyTensor::Tt(x))))
            .unwrap();
        writeln!(w, "this is not json").unwrap();
        writeln!(w, r#"{{"id":42,"op":"upsert","format":"tt","dims":[3]}}"#).unwrap();
        let mut reader = BufReader::new(stream);
        let mut resps = Vec::new();
        for _ in 0..3 {
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            resps.push(wire::decode_response(line.trim_end()).unwrap());
        }
        // The single writer preserves request order.
        assert_eq!(resps[0].id, Some(0));
        assert!(resps[0].error.is_none(), "id 0 is a legitimate request");
        assert_eq!(resps[1].id, None, "unattributable error must not claim id 0");
        assert!(resps[1].error.is_some());
        assert_eq!(resps[2].id, Some(42), "recoverable id is echoed back");
        assert!(resps[2].error.is_some());
        server.shutdown();
    }

    /// Kernel-reported thread count of this process (Linux only).
    #[cfg(target_os = "linux")]
    fn current_threads() -> usize {
        std::fs::read_to_string("/proc/self/status")
            .ok()
            .and_then(|s| {
                s.lines()
                    .find(|l| l.starts_with("Threads:"))
                    .and_then(|l| l.split_whitespace().nth(1))
                    .and_then(|v| v.parse().ok())
            })
            .expect("/proc/self/status readable on linux")
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn pipelined_connection_keeps_thread_count_bounded() {
        use crate::tensor::DenseTensor;
        // Regression: the reply path used to spawn one thread per
        // pipelined request and accumulate the handles without bound.
        // With the single reply-writer the process thread count must stay
        // flat across a 10k-request pipelined connection.
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(5);
        let x = DenseTensor::random(&[2, 2], &mut rng);
        let baseline = current_threads();
        let n = 10_000u64;
        let mut peak = baseline;
        for i in 0..n {
            client
                .send(&ProjectRequest::new(i, AnyTensor::Dense(x.clone())))
                .unwrap();
            if i % 1000 == 0 {
                peak = peak.max(current_threads());
            }
        }
        let mut answered = 0u64;
        for i in 0..n {
            let resp = client.recv().unwrap();
            assert!(resp.error.is_none());
            answered += 1;
            if i % 1000 == 0 {
                peak = peak.max(current_threads());
            }
        }
        assert_eq!(answered, n);
        // The connection itself adds exactly two threads (reader +
        // writer). The slack absorbs unrelated tests running in the same
        // process; the old thread-per-pipelined-request reply path
        // peaked in the thousands here.
        assert!(
            peak <= baseline + 64,
            "thread count must stay bounded: baseline={baseline} peak={peak}"
        );
        server.shutdown();
    }

    #[test]
    fn shutdown_unblocks_idle_connections() {
        // Readers sit in `lines()` between requests; shutdown must
        // half-close them and return instead of waiting forever.
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(6);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let resp = client.roundtrip(&ProjectRequest::new(1, AnyTensor::Tt(x))).unwrap();
        assert_eq!(resp.id, Some(1));
        // Connection stays open and idle while we shut down.
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(
            t0.elapsed() < std::time::Duration::from_secs(10),
            "shutdown must not hang on idle connections"
        );
    }

    #[test]
    fn multiple_clients_share_the_service() {
        let (_coord, server) = start_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..4u64)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let mut rng = Rng::seed_from(c);
                    let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
                    let resp = client
                        .roundtrip(&ProjectRequest::new(c, AnyTensor::Tt(x)))
                        .unwrap();
                    assert_eq!(resp.id, Some(c));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.served(), 4);
        server.shutdown();
    }
}
