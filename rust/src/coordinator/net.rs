//! TCP front-end: newline-delimited JSON requests over plain sockets
//! (std::net — no async runtime offline, and the workload is compute-
//! bound so blocking I/O threads are the right tool).
//!
//! One reader thread per connection; responses are written by the worker
//! completion path through a per-connection writer lock, so pipelined
//! requests from one client overlap in the batcher exactly like requests
//! from different clients.

use super::request::ProjectRequest;
use super::server::Coordinator;
use super::wire;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Handle to a running TCP server.
pub struct NetServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
    served: Arc<AtomicU64>,
}

impl NetServer {
    /// Start serving `coordinator` on `addr` (e.g. `"127.0.0.1:0"` for an
    /// ephemeral port). The coordinator must outlive the server; it is
    /// shared behind an `Arc`.
    pub fn start(coordinator: Arc<Coordinator>, addr: &str) -> std::io::Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let served = Arc::new(AtomicU64::new(0));
        let accept_thread = {
            let stop = Arc::clone(&stop);
            let served = Arc::clone(&served);
            std::thread::spawn(move || {
                accept_loop(listener, coordinator, stop, served);
            })
        };
        Ok(NetServer { addr: local, stop, accept_thread: Some(accept_thread), served })
    }

    /// The bound address (useful with port 0).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// Requests served so far.
    pub fn served(&self) -> u64 {
        self.served.load(Ordering::Relaxed)
    }

    /// Stop accepting and join the accept loop. Established connections
    /// finish their in-flight requests.
    pub fn shutdown(mut self) {
        self.stop_inner();
    }

    fn stop_inner(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_inner();
    }
}

fn accept_loop(
    listener: TcpListener,
    coordinator: Arc<Coordinator>,
    stop: Arc<AtomicBool>,
    served: Arc<AtomicU64>,
) {
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let coordinator = Arc::clone(&coordinator);
                let served = Arc::clone(&served);
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, coordinator, served);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(_) => break,
        }
    }
}

fn handle_connection(
    stream: TcpStream,
    coordinator: Arc<Coordinator>,
    served: Arc<AtomicU64>,
) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    let writer = Arc::new(Mutex::new(stream.try_clone()?));
    let reader = BufReader::new(stream);
    let mut reply_threads = Vec::new();
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        match wire::decode_request(&line) {
            Ok(req) => {
                let id = req.id;
                let rx = coordinator.submit(req);
                let writer = Arc::clone(&writer);
                let served = Arc::clone(&served);
                // Reply asynchronously so the client can pipeline.
                reply_threads.push(std::thread::spawn(move || {
                    let result = rx
                        .recv()
                        .unwrap_or_else(|_| Err("coordinator dropped the request".into()));
                    let out = wire::encode_response(&result, id);
                    let mut w = writer.lock().unwrap();
                    let _ = writeln!(w, "{out}");
                    let _ = w.flush();
                    served.fetch_add(1, Ordering::Relaxed);
                }));
            }
            Err(e) => {
                let mut w = writer.lock().unwrap();
                let _ = writeln!(w, "{}", wire::encode_response(&Err(e), 0));
                let _ = w.flush();
            }
        }
    }
    for t in reply_threads {
        let _ = t.join();
    }
    Ok(())
}

/// Minimal blocking client for the wire protocol (used by tests, the
/// `trp client` subcommand and the serving example).
pub struct NetClient {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl NetClient {
    /// Connect to a server.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> std::io::Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(NetClient { writer: stream, reader })
    }

    /// Send one request (does not wait).
    pub fn send(&mut self, req: &ProjectRequest) -> std::io::Result<()> {
        writeln!(self.writer, "{}", wire::encode_request(req))?;
        self.writer.flush()
    }

    /// Read the next response line.
    pub fn recv(&mut self) -> std::io::Result<wire::WireResponse> {
        let mut line = String::new();
        self.reader.read_line(&mut line)?;
        wire::decode_response(line.trim_end())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    }

    /// Send and wait for the matching response (single in-flight).
    pub fn roundtrip(&mut self, req: &ProjectRequest) -> std::io::Result<wire::WireResponse> {
        self.send(req)?;
        self.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::CoordinatorConfig;
    use crate::rng::Rng;
    use crate::tensor::{AnyTensor, TtTensor};

    fn start_server() -> (Arc<Coordinator>, NetServer) {
        let coord = Arc::new(Coordinator::start(
            CoordinatorConfig { default_k: 8, workers: 2, ..Default::default() },
            None,
        ));
        let server = NetServer::start(Arc::clone(&coord), "127.0.0.1:0").unwrap();
        (coord, server)
    }

    #[test]
    fn tcp_roundtrip() {
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(1);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let resp = client
            .roundtrip(&ProjectRequest::new(5, AnyTensor::Tt(x)))
            .unwrap();
        assert_eq!(resp.id, 5);
        assert_eq!(resp.embedding.unwrap().len(), 8);
        assert!(resp.error.is_none());
        server.shutdown();
    }

    #[test]
    fn pipelined_requests_all_answered() {
        let (_coord, server) = start_server();
        let mut client = NetClient::connect(server.addr()).unwrap();
        let mut rng = Rng::seed_from(2);
        let n = 16;
        for i in 0..n {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            client.send(&ProjectRequest::new(i, AnyTensor::Tt(x))).unwrap();
        }
        let mut ids: Vec<u64> = (0..n).map(|_| client.recv().unwrap().id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..n).collect::<Vec<u64>>());
        server.shutdown();
    }

    #[test]
    fn malformed_line_yields_error_response() {
        let (_coord, server) = start_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        let mut w = stream.try_clone().unwrap();
        writeln!(w, "this is not json").unwrap();
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = wire::decode_response(line.trim_end()).unwrap();
        assert!(resp.error.is_some());
        server.shutdown();
    }

    #[test]
    fn multiple_clients_share_the_service() {
        let (_coord, server) = start_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..4u64)
            .map(|c| {
                std::thread::spawn(move || {
                    let mut client = NetClient::connect(addr).unwrap();
                    let mut rng = Rng::seed_from(c);
                    let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
                    let resp = client
                        .roundtrip(&ProjectRequest::new(c, AnyTensor::Tt(x)))
                        .unwrap();
                    assert_eq!(resp.id, c);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(server.served(), 4);
        server.shutdown();
    }
}
