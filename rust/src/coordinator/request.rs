//! Request/response types of the compression service.

use crate::tensor::AnyTensor;

/// Which execution path served a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnginePath {
    /// Computed by the native Rust projection engine.
    Native,
    /// Computed by a compiled PJRT artifact (name attached).
    Pjrt(String),
}

impl std::fmt::Display for EnginePath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnginePath::Native => write!(f, "native"),
            EnginePath::Pjrt(a) => write!(f, "pjrt:{a}"),
        }
    }
}

/// A projection request: embed `payload` into `R^k` with the service's
/// configured map for this payload signature.
#[derive(Debug, Clone)]
pub struct ProjectRequest {
    /// Caller-assigned id, echoed in the response.
    pub id: u64,
    /// The tensor to embed, in any supported format.
    pub payload: AnyTensor,
}

impl ProjectRequest {
    /// Convenience constructor.
    pub fn new(id: u64, payload: AnyTensor) -> Self {
        Self { id, payload }
    }
}

/// A completed projection.
#[derive(Debug, Clone)]
pub struct ProjectResponse {
    /// Echo of [`ProjectRequest::id`].
    pub id: u64,
    /// The embedding `f(X) ∈ R^k`.
    pub embedding: Vec<f64>,
    /// Which engine computed it.
    pub path: EnginePath,
    /// Time spent queued + batched before execution (microseconds).
    pub queued_us: u64,
    /// Execution time of the (possibly batched) computation (microseconds).
    pub exec_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{DenseTensor, Format};

    #[test]
    fn request_carries_payload_format() {
        let mut rng = Rng::seed_from(1);
        let r = ProjectRequest::new(7, AnyTensor::Dense(DenseTensor::random(&[2, 2], &mut rng)));
        assert_eq!(r.id, 7);
        assert_eq!(r.payload.format(), Format::Dense);
    }

    #[test]
    fn engine_path_display() {
        assert_eq!(EnginePath::Native.to_string(), "native");
        assert_eq!(EnginePath::Pjrt("tt_rp_medium".into()).to_string(), "pjrt:tt_rp_medium");
    }
}
