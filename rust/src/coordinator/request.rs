//! Request/response types of the compression + similarity-search service.

use crate::index::{IndexStats, Neighbor, SnapshotReport};
use crate::tensor::{AnyTensor, Format};

/// Which execution path served a request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EnginePath {
    /// Computed by the native Rust projection engine.
    Native,
    /// Computed by a compiled PJRT artifact (name attached).
    Pjrt(String),
}

impl std::fmt::Display for EnginePath {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EnginePath::Native => write!(f, "native"),
            EnginePath::Pjrt(a) => write!(f, "pjrt:{a}"),
        }
    }
}

/// What the service should do with a request.
///
/// `Project` is the original compression op. The index ops route to the
/// ANN index of the request's map signature: `Insert` and `Query` first
/// flow through the same batched projection path (their payload tensor is
/// embedded exactly like a `Project` payload), while `Delete` and
/// `IndexStats` carry only a signature.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RequestOp {
    /// Embed the payload and return the embedding.
    Project,
    /// Embed the payload and insert it into the signature's index under
    /// the request id.
    Insert,
    /// Embed the payload and return its `k` nearest stored neighbours.
    Query {
        /// Number of neighbours requested.
        k: usize,
    },
    /// Remove a previously inserted item from the signature's index.
    Delete {
        /// The insert-request id of the item to remove.
        target: u64,
    },
    /// Snapshot the signature's index statistics (aggregated across its
    /// shards: mutation counters and sizes sum, the query counter is the
    /// signature total).
    IndexStats,
    /// Persist the signature's index to the coordinator's snapshot
    /// directory (a consistent cut between index ops — the capture
    /// freezes each shard's live pairs inside that shard's sequencer
    /// turn at this op's arrival position, and the files are written
    /// after every lane is released).
    Snapshot,
    /// Reload the signature's index from its newest snapshot sequence
    /// (or legacy single-file snapshot), replacing the live contents —
    /// pairs re-partition into the configured shard count.
    Restore,
    /// Return the full observability snapshot (global counters,
    /// per-signature stage histograms, GEMM profile, trace stats).
    /// Answered directly on the dispatcher thread — it never batches and
    /// never touches a worker. With `reset`, the high-water gauges are
    /// cleared *after* the snapshot is taken.
    Metrics {
        /// Reset resettable gauges (shard skew / parallel high-waters)
        /// after snapshotting.
        reset: bool,
    },
}

/// A request payload: the tensor to embed, or — for ops that carry no
/// data (`Delete`, `IndexStats`) — just the map signature to route on.
#[derive(Debug, Clone)]
pub enum Payload {
    /// A tensor in any supported format.
    Tensor(AnyTensor),
    /// Routing signature only.
    Signature {
        /// Payload format of the signature.
        format: Format,
        /// Input mode sizes of the signature.
        dims: Vec<usize>,
    },
}

impl Payload {
    /// The payload's format tag.
    pub fn format(&self) -> Format {
        match self {
            Payload::Tensor(t) => t.format(),
            Payload::Signature { format, .. } => *format,
        }
    }

    /// The payload's mode sizes.
    pub fn dims(&self) -> &[usize] {
        match self {
            Payload::Tensor(t) => t.dims(),
            Payload::Signature { dims, .. } => dims,
        }
    }

    /// The tensor, when one is carried.
    pub fn tensor(&self) -> Option<&AnyTensor> {
        match self {
            Payload::Tensor(t) => Some(t),
            Payload::Signature { .. } => None,
        }
    }
}

/// A service request: apply `op` to `payload` under the service's
/// configured map for this payload signature.
#[derive(Debug, Clone)]
pub struct ProjectRequest {
    /// Caller-assigned id, echoed in the response. Doubles as the stored
    /// item id for `Insert`.
    pub id: u64,
    /// What to do.
    pub op: RequestOp,
    /// The tensor (or signature) the op applies to.
    pub payload: Payload,
    /// Optional trace context: a caller-chosen correlation id threaded
    /// into every span this request produces and echoed in the response.
    /// When absent and tracing is enabled, the dispatcher assigns one for
    /// spans only — assigned ids are never echoed, so responses stay
    /// bit-identical with tracing on vs off.
    pub trace: Option<u64>,
}

impl ProjectRequest {
    /// Plain projection request (the original service op).
    pub fn new(id: u64, payload: AnyTensor) -> Self {
        Self { id, op: RequestOp::Project, payload: Payload::Tensor(payload), trace: None }
    }

    /// Index insert: embed `payload` and store it under `id`.
    pub fn insert(id: u64, payload: AnyTensor) -> Self {
        Self { id, op: RequestOp::Insert, payload: Payload::Tensor(payload), trace: None }
    }

    /// Index query: embed `payload` and return its `k` nearest neighbours.
    pub fn query(id: u64, payload: AnyTensor, k: usize) -> Self {
        Self { id, op: RequestOp::Query { k }, payload: Payload::Tensor(payload), trace: None }
    }

    /// Index delete: remove item `target` from the index of the
    /// `(format, dims)` signature.
    pub fn delete(id: u64, target: u64, format: Format, dims: Vec<usize>) -> Self {
        Self {
            id,
            op: RequestOp::Delete { target },
            payload: Payload::Signature { format, dims },
            trace: None,
        }
    }

    /// Index statistics for the `(format, dims)` signature.
    pub fn index_stats(id: u64, format: Format, dims: Vec<usize>) -> Self {
        Self {
            id,
            op: RequestOp::IndexStats,
            payload: Payload::Signature { format, dims },
            trace: None,
        }
    }

    /// Persist the `(format, dims)` signature's index to disk.
    pub fn snapshot(id: u64, format: Format, dims: Vec<usize>) -> Self {
        Self {
            id,
            op: RequestOp::Snapshot,
            payload: Payload::Signature { format, dims },
            trace: None,
        }
    }

    /// Reload the `(format, dims)` signature's index from disk.
    pub fn restore(id: u64, format: Format, dims: Vec<usize>) -> Self {
        Self {
            id,
            op: RequestOp::Restore,
            payload: Payload::Signature { format, dims },
            trace: None,
        }
    }

    /// Observability snapshot. Carries an empty signature payload — the
    /// op is global, so there is nothing to route on.
    pub fn metrics(id: u64, reset: bool) -> Self {
        Self {
            id,
            op: RequestOp::Metrics { reset },
            payload: Payload::Signature { format: Format::Dense, dims: vec![] },
            trace: None,
        }
    }

    /// Attach a trace-context id (builder style).
    pub fn with_trace(mut self, trace: u64) -> Self {
        self.trace = Some(trace);
        self
    }
}

/// A completed request.
#[derive(Debug, Clone)]
pub struct ProjectResponse {
    /// Echo of [`ProjectRequest::id`].
    pub id: u64,
    /// The embedding `f(X) ∈ R^k` (empty for signature-only ops).
    pub embedding: Vec<f64>,
    /// Nearest neighbours (`Query` responses only).
    pub neighbors: Option<Vec<Neighbor>>,
    /// Whether the target existed (`Delete` responses only).
    pub removed: Option<bool>,
    /// Index statistics (`IndexStats` responses only).
    pub index: Option<IndexStats>,
    /// Where/what a snapshot wrote (`Snapshot` responses only).
    pub snapshot: Option<SnapshotReport>,
    /// Items reloaded (`Restore` responses only).
    pub restored: Option<u64>,
    /// Observability snapshot (`Metrics` responses only).
    pub metrics: Option<crate::obs::ObsSnapshot>,
    /// Echo of the caller-supplied trace context, when one was supplied.
    /// Dispatcher-assigned span ids are never echoed here.
    pub trace: Option<u64>,
    /// Which engine computed it.
    pub path: EnginePath,
    /// Time spent queued + batched before execution (microseconds).
    pub queued_us: u64,
    /// Execution time of the (possibly batched) computation (microseconds).
    pub exec_us: u64,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::DenseTensor;

    #[test]
    fn request_carries_payload_format() {
        let mut rng = Rng::seed_from(1);
        let r = ProjectRequest::new(7, AnyTensor::Dense(DenseTensor::random(&[2, 2], &mut rng)));
        assert_eq!(r.id, 7);
        assert_eq!(r.op, RequestOp::Project);
        assert_eq!(r.payload.format(), Format::Dense);
        assert!(r.payload.tensor().is_some());
    }

    #[test]
    fn signature_payloads_carry_no_tensor() {
        let r = ProjectRequest::delete(3, 17, Format::Tt, vec![3, 3, 3]);
        assert_eq!(r.op, RequestOp::Delete { target: 17 });
        assert_eq!(r.payload.format(), Format::Tt);
        assert_eq!(r.payload.dims(), &[3, 3, 3]);
        assert!(r.payload.tensor().is_none());
        let s = ProjectRequest::index_stats(4, Format::Cp, vec![2, 2]);
        assert_eq!(s.op, RequestOp::IndexStats);
        let p = ProjectRequest::snapshot(5, Format::Tt, vec![3, 3]);
        assert_eq!(p.op, RequestOp::Snapshot);
        assert!(p.payload.tensor().is_none());
        let r = ProjectRequest::restore(6, Format::Tt, vec![3, 3]);
        assert_eq!(r.op, RequestOp::Restore);
        assert!(r.payload.tensor().is_none());
        let m = ProjectRequest::metrics(8, true);
        assert_eq!(m.op, RequestOp::Metrics { reset: true });
        assert!(m.payload.tensor().is_none());
        assert!(m.payload.dims().is_empty());
    }

    #[test]
    fn query_constructor_carries_k() {
        let mut rng = Rng::seed_from(2);
        let r = ProjectRequest::query(
            9,
            AnyTensor::Dense(DenseTensor::random(&[2, 2], &mut rng)),
            5,
        );
        assert_eq!(r.op, RequestOp::Query { k: 5 });
    }

    #[test]
    fn trace_context_defaults_off_and_attaches() {
        let r = ProjectRequest::metrics(1, false);
        assert_eq!(r.trace, None);
        let r = ProjectRequest::index_stats(2, Format::Tt, vec![3, 3]).with_trace(0xABCD);
        assert_eq!(r.trace, Some(0xABCD));
    }

    #[test]
    fn engine_path_display() {
        assert_eq!(EnginePath::Native.to_string(), "native");
        assert_eq!(EnginePath::Pjrt("tt_rp_medium".into()).to_string(), "pjrt:tt_rp_medium");
    }
}
