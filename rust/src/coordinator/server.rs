//! The coordinator server: bounded ingress queue → dispatcher thread →
//! (per-map native dynamic batchers → worker pool | per-artifact dynamic
//! batchers → PJRT engine).
//!
//! Both execution paths are batch-first: the native route accumulates
//! requests per map signature exactly like the PJRT route does per
//! artifact, and a flushed batch of `B` requests executes through
//! [`crate::projections::Projection::project_batch_into`] calls on pooled
//! [`crate::projections::Workspace`]s — there is no per-item `project`
//! call anywhere in the worker loop. A flushed batch of pure projections
//! is split into per-worker sub-batches (still batched calls) so a single
//! hot signature saturates the whole pool instead of one worker.
//!
//! Index ops ([`RequestOp::Insert`], [`RequestOp::Query`],
//! [`RequestOp::Delete`], [`RequestOp::IndexStats`]) ride the same native
//! batchers: inserts and queries are embedded inside the flush's batched
//! projection call, then applied to the signature's ANN index strictly in
//! arrival order — within a flush by walking the items in order (runs of
//! consecutive queries score as one batched GEMM), across flushes via the
//! per-signature FIFO sequencer ([`super::state::IndexSlot`]).

use super::batcher::{ArrivalRate, Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{EnginePath, Payload, ProjectRequest, ProjectResponse, RequestOp};
use super::router::{RouteTarget, Router};
use super::state::{
    IndexRegistry, MapKey, MapKind, PackedParams, ProjectionRegistry, RestorePlan, SharedIndex,
    WorkspacePool,
};
use crate::index::{
    combine_stats, shard_of, wal, AnnIndex, BackendKind, IndexSnapshot, IndexStats, LshConfig,
    Neighbor, SnapshotReport, WalConfig, WalFsync,
};
use crate::obs::{Span, Stage};
use crate::projections::Workspace;
use crate::runtime::{pack, ArtifactKind, PjrtEngine};
use crate::tensor::{AnyTensor, Format};
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads executing projections.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Dynamic-batcher deadline (µs) — applies to both the PJRT and the
    /// native batchers.
    pub max_delay_us: u64,
    /// Native-path batch-size cap: requests sharing a map signature
    /// accumulate up to this count (or the deadline) and execute as one
    /// flush. `1` restores item-at-a-time dispatch.
    pub native_max_batch: usize,
    /// Adapt the native flush size to the observed arrival rate, with
    /// `native_max_batch` as the cap (see [`ArrivalRate`]). Off = always
    /// wait for the full `native_max_batch`.
    pub adaptive_batch: bool,
    /// Master seed for the projection registry.
    pub master_seed: u64,
    /// ANN backend for per-signature indexes.
    pub index_backend: BackendKind,
    /// LSH shape used when `index_backend` is [`BackendKind::Lsh`]
    /// (static, or derived via [`LshConfig::auto`] by the CLI).
    pub lsh: LshConfig,
    /// Shards per signature index (`trp serve --index-shards`). 1 =
    /// unsharded. Each shard owns its own sequencer lane, so a single hot
    /// signature's index phases spread across the worker pool; queries
    /// scatter to every shard and gather via a k-way merge, bit-identical
    /// to the unsharded answers (`crate::index::sharded` docs).
    pub index_shards: usize,
    /// Directory index snapshots are written to and reloaded from.
    /// `None` disables the `snapshot`/`restore` wire ops and periodic
    /// snapshots (they reply with an error).
    pub snapshot_dir: Option<std::path::PathBuf>,
    /// Write a background snapshot of a signature's index after this
    /// many mutations (inserts + effective deletes) since its last
    /// snapshot. `0` disables periodic snapshots. The write runs inside
    /// the signature's sequencer turn, so it is a consistent cut between
    /// flushes exactly like an explicit `snapshot` op.
    pub snapshot_every_ops: u64,
    /// Snapshot rotation depth: keep this many sequenced snapshot files
    /// per signature, pruning the oldest after each successful write
    /// (minimum 1; restore always reads the newest).
    pub snapshot_keep: usize,
    /// Map policy for native TT-format requests: TT rank.
    pub default_tt_rank: usize,
    /// Map policy for native CP-format requests: CP rank.
    pub default_cp_rank: usize,
    /// Embedding dimension for native-routed requests.
    pub default_k: usize,
    /// Dense inputs above this size use very sparse RP instead of Gaussian.
    pub dense_gaussian_limit: usize,
    /// Request tracing (`trp serve --trace-dir`): spans are recorded
    /// lock-free and drained to rotated JSONL files. `None` disables
    /// tracing entirely — the per-request cost is then a single relaxed
    /// atomic load, and responses are bit-identical either way.
    pub trace: Option<crate::obs::TraceConfig>,
    /// Write-ahead log directory (`trp serve --wal-dir`). Every insert
    /// and delete is appended to a per-signature, per-shard-lane
    /// segmented log inside its sequencer turn, group-commit fsynced once
    /// per lane per flush, and replayed over the newest snapshot
    /// checkpoint at startup ([`IndexRegistry::recover_wal`]). `None`
    /// disables the WAL entirely — responses are bit-identical either
    /// way. Requires `snapshot_dir` (checkpoints are snapshot cuts).
    pub wal_dir: Option<std::path::PathBuf>,
    /// WAL segment rotation cap in bytes (`--wal-segment-cap`).
    pub wal_segment_cap: u64,
    /// WAL group-commit policy (`--wal-fsync {flush,every-<n>}`):
    /// `Flush` fsyncs every flush that appended (acked ⇒ durable);
    /// `EveryN(n)` trades the crash-durability of up to `n` acked ops
    /// per lane for fewer fsyncs.
    pub wal_fsync: WalFsync,
    /// SLO policy (`trp serve --slo <file.toml>`): per-signature latency
    /// and error-rate objectives evaluated as multi-window burn rates by
    /// a background engine fed from the always-on metrics registry.
    /// `None` disables the engine entirely — it only ever *reads*
    /// metrics, so responses are bit-identical either way.
    pub slo: Option<crate::obs::SloConfig>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_cap: 1024,
            max_delay_us: 2_000,
            native_max_batch: 16,
            adaptive_batch: true,
            master_seed: 0xC0FFEE,
            index_backend: BackendKind::Flat,
            lsh: LshConfig::default(),
            index_shards: 1,
            snapshot_dir: None,
            snapshot_every_ops: 0,
            snapshot_keep: super::state::DEFAULT_SNAPSHOT_KEEP,
            default_tt_rank: 5,
            default_cp_rank: 25,
            default_k: 64,
            dense_gaussian_limit: 1 << 20,
            trace: None,
            wal_dir: None,
            wal_segment_cap: wal::DEFAULT_SEGMENT_CAP,
            wal_fsync: WalFsync::Flush,
            slo: None,
        }
    }
}

/// Reply type: the response or a failure message.
pub type Reply = Result<ProjectResponse, String>;

struct Envelope {
    req: ProjectRequest,
    submit_us: u64,
    reply: SyncSender<Reply>,
    /// Trace-context id threaded into this request's spans: the caller's
    /// `req.trace` when supplied, otherwise a dispatcher-assigned id
    /// (tracing enabled only). Never echoed in responses unless the
    /// caller supplied it — see [`ProjectRequest::trace`].
    span_trace: Option<u64>,
}

struct Shared {
    registry: ProjectionRegistry,
    indexes: IndexRegistry,
    engine: Option<PjrtEngine>,
    metrics: Metrics,
    /// Per-signature counters + stage histograms (always on: recording
    /// is pure atomics and never touches the request path's results).
    /// `Arc` so the SLO engine's sampler thread reads the same registry
    /// without holding the whole `Shared` alive.
    sigs: Arc<crate::obs::MetricsRegistry>,
    /// Trace recorder, when `cfg.trace` is set.
    trace: Option<Arc<crate::obs::TraceRecorder>>,
    /// Flush ids for trace spans (monotonic across both lanes).
    next_flush_id: std::sync::atomic::AtomicU64,
    /// Dispatcher-assigned trace-context ids for requests that arrive
    /// without one (tracing enabled only).
    next_trace_id: std::sync::atomic::AtomicU64,
    /// SLO burn-rate engine, when `cfg.slo` is set.
    slo: Option<Arc<crate::obs::SloEngine>>,
    workspaces: WorkspacePool,
    cfg: CoordinatorConfig,
    epoch: Instant,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Full observability snapshot; with `reset`, the resettable
    /// high-water gauges are cleared *after* the snapshot is taken.
    fn obs_snapshot(&self, reset: bool) -> crate::obs::ObsSnapshot {
        self.refresh_now_gauges();
        let snap = crate::obs::ObsSnapshot {
            global: self.metrics.snapshot(),
            signatures: self.sigs.snapshot(),
            gemm: crate::obs::gemm_stats_snapshot(),
            trace: self.trace.as_ref().map(|t| t.stats()).unwrap_or_default(),
            slo: self.slo.as_ref().map(|s| s.status()).unwrap_or_default(),
        };
        if reset {
            self.metrics.reset_high_water();
        }
        snap
    }

    /// Store the *current* shard skew / overlap values (the companions of
    /// the `index_shard_max_skew` / `index_shard_parallel` high-waters)
    /// by walking the live index slots at snapshot time.
    fn refresh_now_gauges(&self) {
        let mut skew = 0u64;
        let mut parallel = 0u64;
        for slot in self.indexes.all_slots() {
            skew = skew.max(slot.max_skew());
            parallel = parallel.max(slot.active_passes());
            // Replay-cost signal: ops logged above the last checkpoint.
            self.sigs
                .get(&slot.key.label())
                .wal_lag
                .store(slot.wal_lag(), Ordering::Relaxed);
        }
        self.metrics.index_shard_skew_now.store(skew, Ordering::Relaxed);
        self.metrics.index_shard_parallel_now.store(parallel, Ordering::Relaxed);
    }
}

/// The coordinator service handle.
pub struct Coordinator {
    shared: Arc<Shared>,
    tx: Option<SyncSender<Envelope>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a coordinator. Pass a loaded [`PjrtEngine`] to enable the
    /// compiled path; with `None` everything runs on the native engine.
    ///
    /// # Panics
    /// When `snapshot_every_ops > 0` without a `snapshot_dir`: a server
    /// that believes periodic durability is on but can never write a
    /// snapshot must fail at startup, not at the first crash. Likewise
    /// when `wal_dir` is set without a `snapshot_dir` (WAL checkpoints
    /// are snapshot cuts), or when WAL recovery fails — serving over a
    /// corrupt or silently rolled-back corpus is worse than refusing to
    /// start.
    pub fn start(cfg: CoordinatorConfig, engine: Option<PjrtEngine>) -> Self {
        assert!(
            cfg.snapshot_every_ops == 0 || cfg.snapshot_dir.is_some(),
            "snapshot_every_ops requires snapshot_dir"
        );
        assert!(
            cfg.wal_dir.is_none() || cfg.snapshot_dir.is_some(),
            "wal_dir requires snapshot_dir (WAL checkpoints are snapshot cuts)"
        );
        // One clock epoch shared with the trace recorder, so span
        // timestamps line up with `queued_us`/`exec_us` in responses.
        let epoch = Instant::now();
        let trace = cfg.trace.as_ref().and_then(|tc| {
            match crate::obs::TraceRecorder::start(tc.clone(), epoch) {
                Ok(rec) => {
                    // GEMM shape profiling rides along with tracing; it
                    // observes timings only, never results.
                    crate::obs::set_gemm_profiling(true);
                    Some(rec)
                }
                Err(e) => {
                    eprintln!("[coordinator] tracing disabled: {e}");
                    None
                }
            }
        });
        let sigs = Arc::new(crate::obs::MetricsRegistry::new());
        let slo = cfg.slo.clone().and_then(|sc| {
            match crate::obs::SloEngine::start(sc, Arc::clone(&sigs)) {
                Ok(engine) => Some(engine),
                Err(e) => {
                    eprintln!("[coordinator] slo engine disabled: {e}");
                    None
                }
            }
        });
        let shared = Arc::new(Shared {
            registry: ProjectionRegistry::new(cfg.master_seed),
            indexes: IndexRegistry::new(cfg.master_seed, cfg.index_backend, cfg.lsh)
                .with_snapshot_dir(cfg.snapshot_dir.clone())
                .with_snapshot_keep(cfg.snapshot_keep)
                .with_shards(cfg.index_shards)
                .with_wal(cfg.wal_dir.clone().map(|dir| WalConfig {
                    dir,
                    segment_cap: cfg.wal_segment_cap.max(1),
                    fsync: cfg.wal_fsync,
                })),
            engine,
            metrics: Metrics::new(),
            sigs,
            trace,
            next_flush_id: std::sync::atomic::AtomicU64::new(0),
            next_trace_id: std::sync::atomic::AtomicU64::new(1),
            slo,
            workspaces: WorkspacePool::new(),
            cfg: cfg.clone(),
            epoch,
        });
        // With adaptation on, the gauge is a high-water mark of chosen
        // targets (starts at 0); off, it is simply the configured cap.
        let initial_flush_max = if cfg.adaptive_batch {
            0
        } else {
            cfg.native_max_batch.max(1) as u64
        };
        shared
            .metrics
            .native_flush_max
            .store(initial_flush_max, Ordering::Relaxed);
        // WAL crash recovery runs before the dispatcher exists, so the
        // first request already observes the pre-crash state (no-op with
        // the WAL off). A failure is fatal by design — see `# Panics`.
        let recovered = shared.indexes.recover_wal();
        assert!(
            recovered.is_ok(),
            "wal recovery failed: {}",
            recovered.as_ref().err().map(String::as_str).unwrap_or("")
        );
        if let Ok((sigs, replayed)) = recovered {
            shared.metrics.wal_replayed.fetch_add(replayed, Ordering::Relaxed);
            if replayed > 0 {
                eprintln!(
                    "[coordinator] wal recovery: replayed {replayed} record(s) across {sigs} signature(s)"
                );
            }
        }
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_cap);
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatcher_loop(shared, rx))
        };
        Self { shared, tx: Some(tx), dispatcher: Some(dispatcher) }
    }

    /// The trace-context id `req`'s spans will carry: the caller's
    /// `req.trace` when supplied; otherwise, with tracing enabled, a
    /// freshly assigned id (so every traced request is correlatable even
    /// when the client sends no context). `None` with tracing off and no
    /// client context. The front-end calls this *before*
    /// [`submit_with_span`] so its socket-side spans share the id.
    ///
    /// [`submit_with_span`]: Coordinator::submit_with_span
    pub fn span_trace_for(&self, req: &ProjectRequest) -> Option<u64> {
        req.trace.or_else(|| {
            self.shared
                .trace
                .as_ref()
                .map(|_| self.shared.next_trace_id.fetch_add(1, Ordering::Relaxed))
        })
    }

    /// Submit a request; blocks if the ingress queue is full
    /// (backpressure). Returns the channel the response arrives on.
    pub fn submit(&self, req: ProjectRequest) -> Receiver<Reply> {
        let span_trace = self.span_trace_for(&req);
        self.submit_with_span(req, span_trace)
    }

    /// [`submit`](Coordinator::submit) with an explicit span trace-
    /// context id (from [`span_trace_for`](Coordinator::span_trace_for)),
    /// so a network front-end can tag its recv/write spans with the same
    /// id the in-flight spans will carry.
    pub fn submit_with_span(
        &self,
        req: ProjectRequest,
        span_trace: Option<u64>,
    ) -> Receiver<Reply> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let env = Envelope {
            req,
            submit_us: self.shared.now_us(),
            reply: reply_tx,
            span_trace,
        };
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        // A closed ingress (shutdown racing a late submit, or a dead
        // dispatcher) degrades to an error reply on the caller's channel
        // — never a panic in the submitting connection thread.
        let undelivered = match self.tx.as_ref() {
            Some(tx) => tx.send(env).err().map(|e| e.0),
            None => Some(env),
        };
        if let Some(env) = undelivered {
            let _ = env.reply.send(Err("coordinator is shut down".into()));
        }
        reply_rx
    }

    /// Submit and wait for the response.
    pub fn project_blocking(&self, req: ProjectRequest) -> Reply {
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Err("coordinator dropped the request".into()))
    }

    /// Metrics snapshot (with the current-value shard gauges refreshed).
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.shared.refresh_now_gauges();
        self.shared.metrics.snapshot()
    }

    /// Full observability snapshot — global counters, per-signature stage
    /// histograms, GEMM profile, trace stats — exactly what the `metrics`
    /// wire op returns. With `reset`, the resettable high-water gauges
    /// clear *after* the snapshot is taken.
    pub fn obs_snapshot(&self, reset: bool) -> crate::obs::ObsSnapshot {
        self.shared.obs_snapshot(reset)
    }

    /// The trace recorder, when tracing is enabled (the TCP front-end
    /// records its socket-side spans through this).
    pub fn trace(&self) -> Option<Arc<crate::obs::TraceRecorder>> {
        self.shared.trace.as_ref().map(Arc::clone)
    }

    /// Microseconds since the coordinator's clock epoch (the time base of
    /// every span and `queued_us`/`exec_us` field).
    pub fn now_us(&self) -> u64 {
        self.shared.now_us()
    }

    /// Whether a PJRT engine is attached.
    pub fn has_pjrt(&self) -> bool {
        self.shared.engine.is_some()
    }

    /// Out-of-band access to a signature's index slot (tests, ops
    /// tooling). Creates the slot lazily exactly like the first index op
    /// for the signature would.
    pub fn index_slot(&self, key: &MapKey) -> SharedIndex {
        self.shared.indexes.get_or_create(key)
    }

    /// Crash recovery: load every index snapshot in `dir` into the
    /// registry. Call before serving traffic (`trp serve --restore`);
    /// per-signature `restore` wire ops cover runtime reloads. Returns
    /// `(signatures, items)` restored.
    pub fn restore_from(&self, dir: &std::path::Path) -> Result<(usize, u64), String> {
        self.shared.indexes.restore_all(dir)
    }

    /// Graceful shutdown: drains queued requests, then joins all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        // Workers are joined (the dispatcher drops the pool on exit), so
        // every span has been recorded; drain the ring before returning
        // to leave complete trace files behind.
        if let Some(t) = &self.shared.trace {
            t.shutdown();
        }
        // Stop the SLO sampler after the workers: the final registry
        // state is then complete for its last evaluation tick.
        if let Some(s) = &self.shared.slo {
            s.shutdown();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Items carried through a PJRT batch.
struct BatchItem {
    env: Envelope,
}

/// Per-signature native batching state: the dynamic batcher plus the
/// arrival-rate estimator that adapts its flush threshold (estimating per
/// signature, not globally — a sparse stream must not inherit the
/// aggregate arrival rate of the busy ones and stall at the deadline).
struct NativeLane {
    batcher: Batcher<Envelope>,
    arrivals: ArrivalRate,
}

fn dispatcher_loop(shared: Arc<Shared>, rx: Receiver<Envelope>) {
    // Build the routing table from the attached engine's artifacts.
    let mut router = Router::new();
    let mut artifact_batch_cfg: HashMap<String, BatcherConfig> = HashMap::new();
    if let Some(engine) = &shared.engine {
        let mut specs: Vec<_> = engine
            .artifact_names()
            .iter()
            .filter_map(|n| engine.spec(n).cloned())
            .collect();
        // Later registrations shadow earlier ones for identical
        // signatures: put pallas-path artifacts first so their fused
        // (non-pallas) twins win the route. On CPU the interpret-mode
        // pallas lowering is ~20× slower (EXPERIMENTS.md §Perf); on a
        // real TPU the preference would flip.
        specs.sort_by_key(|s| std::cmp::Reverse(s.use_pallas));
        router.register_artifacts(specs.iter());
        for s in &specs {
            artifact_batch_cfg.insert(
                s.name.clone(),
                BatcherConfig { max_batch: s.batch, max_delay_us: shared.cfg.max_delay_us },
            );
        }
    }
    let pool = ThreadPool::new(shared.cfg.workers, shared.cfg.queue_cap);
    let mut batchers: HashMap<String, Batcher<BatchItem>> = HashMap::new();
    // Native requests batch per map signature, mirroring the per-artifact
    // PJRT batchers: size native_max_batch (adaptively shrunk towards the
    // observed arrival rate) or the shared deadline.
    let native_cap = shared.cfg.native_max_batch.max(1);
    let native_cfg = BatcherConfig {
        max_batch: native_cap,
        max_delay_us: shared.cfg.max_delay_us,
    };
    let mut native_lanes: HashMap<MapKey, NativeLane> = HashMap::new();

    loop {
        // Sleep until the nearest batch deadline (or a coarse tick).
        let now = shared.now_us();
        let next_deadline = batchers
            .values()
            .filter_map(|b| b.deadline_us())
            .chain(native_lanes.values().filter_map(|l| l.batcher.deadline_us()))
            .min()
            .unwrap_or(now + 5_000);
        let wait = Duration::from_micros(next_deadline.saturating_sub(now).max(100));
        match rx.recv_timeout(wait) {
            // Observability snapshots are answered synchronously on the
            // dispatcher thread: they never batch, never queue behind a
            // flush, and never touch a worker — a metrics poll must not
            // perturb serving.
            Ok(env) if matches!(env.req.op, RequestOp::Metrics { .. }) => {
                let reset = matches!(env.req.op, RequestOp::Metrics { reset: true });
                let snap = shared.obs_snapshot(reset);
                let t1 = shared.now_us();
                shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
                shared.metrics.e2e_latency.record(t1.saturating_sub(env.submit_us));
                let _ = env.reply.send(Ok(ProjectResponse {
                    id: env.req.id,
                    embedding: Vec::new(),
                    neighbors: None,
                    removed: None,
                    index: None,
                    snapshot: None,
                    restored: None,
                    metrics: Some(snap),
                    trace: env.req.trace,
                    path: EnginePath::Native,
                    queued_us: 0,
                    exec_us: t1.saturating_sub(env.submit_us),
                }));
            }
            Ok(env) => {
                // Index ops always run native (compiled artifacts only
                // cover pure projection). Project/Insert/Query without a
                // tensor payload are unanswerable — reject them here so a
                // malformed request can never panic a worker.
                let needs_tensor = matches!(
                    env.req.op,
                    RequestOp::Project | RequestOp::Insert | RequestOp::Query { .. }
                );
                let target = if needs_tensor && env.req.payload.tensor().is_none() {
                    None
                } else {
                    match (env.req.op, &env.req.payload) {
                        (RequestOp::Project, Payload::Tensor(t)) => Some(router.route(t)),
                        _ => Some(RouteTarget::Native),
                    }
                };
                match target {
                    None => {
                        shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
                        // Error replies count toward end-to-end latency
                        // too — a dashboard that only sees successes
                        // under-reports a failing service.
                        shared
                            .metrics
                            .e2e_latency
                            .record(shared.now_us().saturating_sub(env.submit_us));
                        let _ = env
                            .reply
                            .send(Err("this op requires a tensor payload".into()));
                    }
                    Some(RouteTarget::Native) => {
                        let key = native_map_key(&shared, &env.req);
                        let lane = native_lanes.entry(key.clone()).or_insert_with(|| {
                            NativeLane {
                                batcher: Batcher::new(native_cfg),
                                arrivals: ArrivalRate::new(shared.cfg.max_delay_us),
                            }
                        });
                        if shared.cfg.adaptive_batch {
                            lane.arrivals.observe(shared.now_us());
                            let target_batch = lane.arrivals.suggest(native_cap);
                            // High-water across lanes: a last-write gauge
                            // would flap between unrelated signatures.
                            shared
                                .metrics
                                .native_flush_max
                                .fetch_max(target_batch as u64, Ordering::Relaxed);
                            lane.batcher.set_max_batch(target_batch);
                        }
                        // Read before pushing: a flush clears the
                        // batcher's open tick (a fresh single-item flush
                        // opened just now).
                        let now_push = shared.now_us();
                        let opened = lane.batcher.opened_us().unwrap_or(now_push);
                        if let Some(batch) = lane.batcher.push(env, now_push) {
                            dispatch_native_batch(&shared, &pool, key, batch, opened);
                        }
                    }
                    Some(RouteTarget::Pjrt(name)) => {
                        let cfg = artifact_batch_cfg[&name];
                        let b = batchers
                            .entry(name.clone())
                            .or_insert_with(|| Batcher::new(cfg));
                        if let Some(batch) = b.push(BatchItem { env }, shared.now_us()) {
                            dispatch_pjrt(&shared, &pool, &name, batch);
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Drain: flush every pending batch, then stop.
                // lint:allow(unordered-iteration): each flush is one signature's whole queue; dispatch order across signatures cannot affect any reply.
                for (name, b) in batchers.iter_mut() {
                    if let Some(batch) = b.flush() {
                        dispatch_pjrt(&shared, &pool, name, batch);
                    }
                }
                // lint:allow(unordered-iteration): same argument as the PJRT drain above — per-signature flushes are independent.
                for (key, lane) in native_lanes.iter_mut() {
                    let opened = lane.batcher.opened_us().unwrap_or_else(|| shared.now_us());
                    if let Some(batch) = lane.batcher.flush() {
                        dispatch_native_batch(&shared, &pool, key.clone(), batch, opened);
                    }
                }
                break;
            }
        }
        // Deadline sweep on every iteration — arrivals included — so a
        // sustained request stream (recv_timeout always returning Ok
        // before the timeout fires) cannot starve an expired batch past
        // its max_delay_us deadline.
        let now = shared.now_us();
        // lint:allow(unordered-iteration): deadline expiry flushes are independent per signature; sweep order cannot affect any reply.
        for (name, b) in batchers.iter_mut() {
            if let Some(batch) = b.poll(now) {
                dispatch_pjrt(&shared, &pool, name, batch);
            }
        }
        // lint:allow(unordered-iteration): same argument as the PJRT sweep above — per-signature deadline flushes are independent.
        for (key, lane) in native_lanes.iter_mut() {
            let opened = lane.batcher.opened_us().unwrap_or(now);
            if let Some(batch) = lane.batcher.poll(now) {
                dispatch_native_batch(&shared, &pool, key.clone(), batch, opened);
            }
        }
        // MapKey dims come verbatim from (possibly remote) payloads, so
        // distinct signatures are unbounded over a server's lifetime;
        // evict idle lanes past a high-water mark to bound both the map's
        // memory and the sweep above.
        const MAX_IDLE_NATIVE_LANES: usize = 1024;
        if native_lanes.len() > MAX_IDLE_NATIVE_LANES {
            native_lanes.retain(|_, l| !l.batcher.is_empty());
        }
    }
    // Dropping the pool joins the workers after queued jobs finish.
    drop(pool);
}

/// Map policy for native-path requests (tensor or signature-only: the
/// policy depends only on format and dims).
fn native_map_key(shared: &Shared, req: &ProjectRequest) -> MapKey {
    let cfg = &shared.cfg;
    let dims = req.payload.dims().to_vec();
    match req.payload.format() {
        Format::Tt => MapKey {
            kind: MapKind::Tt { rank: cfg.default_tt_rank },
            dims,
            k: cfg.default_k,
        },
        Format::Cp => MapKey {
            kind: MapKind::Cp { rank: cfg.default_cp_rank },
            dims,
            k: cfg.default_k,
        },
        Format::Dense => {
            let numel: usize = dims.iter().product();
            let kind = if numel <= cfg.dense_gaussian_limit {
                MapKind::Gaussian
            } else {
                MapKind::VerySparse
            };
            MapKey { kind, dims, k: cfg.default_k }
        }
    }
}

/// Dispatch one flushed native batch to the worker pool.
///
/// Pure-projection flushes are split into per-worker sub-batches (each
/// still one batched execution) so single-signature saturation keeps the
/// whole pool busy instead of serializing on one worker. Flushes carrying
/// index ops run as a single job holding a FIFO ticket on each shard lane
/// the flush touches ([`super::state::IndexSlot::issue_tickets`], called
/// here on the dispatcher thread so every lane's ticket order equals
/// arrival order): within a flush ops apply in arrival order, across
/// flushes the lane tickets keep same-shard index phases ordered even
/// when the jobs land on different workers — and flushes touching
/// disjoint shards advance in parallel, which is what lets a single hot
/// signature saturate the pool during bulk ingest.
///
/// Scatter ops (query, stats, snapshot, restore) take the signature-level
/// epoch barrier — a ticket on every lane. Periodic snapshots capture at
/// the end of a mutation flush, so the flush that crosses the mutation
/// threshold is granted the barrier too — ordinary ingest flushes keep
/// their targeted-lane fan-out.
fn dispatch_native_batch(
    shared: &Arc<Shared>,
    pool: &ThreadPool,
    key: MapKey,
    batch: Vec<Envelope>,
    opened_us: u64,
) {
    let has_index_ops = batch
        .iter()
        .any(|env| !matches!(env.req.op, RequestOp::Project));
    if has_index_ops {
        let slot = shared.indexes.get_or_create(&key);
        // Periodic snapshots need every lane ticketed to capture — but
        // only the flush that actually crosses the threshold pays for
        // the barrier; ordinary ingest flushes keep their targeted-lane
        // fan-out. The threshold read races with in-flight cuts, which
        // only shifts the capture to a nearby flush (the worker
        // re-checks under its own tickets).
        let periodic_barrier = shared.cfg.snapshot_every_ops > 0 && {
            let bound = batch
                .iter()
                .filter(|env| {
                    matches!(env.req.op, RequestOp::Insert | RequestOp::Delete { .. })
                })
                .count() as u64;
            bound > 0
                && slot.pending_mutations() + bound >= shared.cfg.snapshot_every_ops
        };
        let needs_barrier = periodic_barrier
            || batch.iter().any(|env| {
                matches!(
                    env.req.op,
                    RequestOp::Query { .. }
                        | RequestOp::IndexStats
                        | RequestOp::Snapshot
                        | RequestOp::Restore
                )
            });
        let tickets = if needs_barrier || slot.shards() == 1 {
            slot.issue_barrier()
        } else {
            let mut shards: Vec<usize> = batch
                .iter()
                .filter_map(|env| match env.req.op {
                    RequestOp::Insert => Some(shard_of(env.req.id, slot.shards())),
                    RequestOp::Delete { target } => Some(shard_of(target, slot.shards())),
                    _ => None,
                })
                .collect();
            shards.sort_unstable();
            shards.dedup();
            slot.issue_tickets(&shards)
        };
        submit_native_job(shared, pool, key, batch, opened_us, Some((slot, tickets)));
        return;
    }
    let workers = shared.cfg.workers.max(1);
    if workers == 1 || batch.len() < 2 {
        submit_native_job(shared, pool, key, batch, opened_us, None);
        return;
    }
    let chunk = batch.len().div_ceil(workers);
    let mut remaining = batch;
    while remaining.len() > chunk {
        let rest = remaining.split_off(chunk);
        submit_native_job(shared, pool, key.clone(), remaining, opened_us, None);
        remaining = rest;
    }
    submit_native_job(shared, pool, key, remaining, opened_us, None);
}

fn submit_native_job(
    shared: &Arc<Shared>,
    pool: &ThreadPool,
    key: MapKey,
    batch: Vec<Envelope>,
    opened_us: u64,
    index_turn: Option<(SharedIndex, Vec<(usize, u64)>)>,
) {
    let shared = Arc::clone(shared);
    pool.submit(move || run_native_batch(&shared, key, batch, opened_us, index_turn));
}

/// Per-request reply metadata carried through one native flush.
struct NativeItem {
    op: RequestOp,
    id: u64,
    submit_us: u64,
    reply: SyncSender<Reply>,
    /// Row of this item's embedding in the flush's `out` buffer
    /// (`None` for signature-only ops).
    row: Option<usize>,
    /// Caller-supplied trace context, echoed in the response.
    trace: Option<u64>,
    /// Trace context carried by this item's spans and exemplars
    /// (caller-supplied or dispatcher-assigned; never echoed unless
    /// caller-supplied).
    span: Option<u64>,
}

/// Execute one native job: resolve the shared map, run every tensor in
/// the batch through a single `project_batch_into` call on a pooled
/// workspace and a pooled output buffer, apply index ops (one pass per
/// ticketed shard lane, each inside that lane's sequencer turn), then
/// split the `[B, k]` output into per-request replies.
fn run_native_batch(
    shared: &Arc<Shared>,
    key: MapKey,
    batch: Vec<Envelope>,
    opened_us: u64,
    index_turn: Option<(SharedIndex, Vec<(usize, u64)>)>,
) {
    let k = key.k;
    let sig = shared.sigs.get(&key.label());
    let flush_id = shared.next_flush_id.fetch_add(1, Ordering::Relaxed);
    let tr = shared.trace.as_deref();
    // Split payloads from reply metadata: `project_batch_into` takes the
    // payload slice by reference, so no tensor is cloned.
    let mut payloads: Vec<AnyTensor> = Vec::with_capacity(batch.len());
    let mut items: Vec<NativeItem> = Vec::with_capacity(batch.len());
    for env in batch {
        let row = match env.req.payload {
            Payload::Tensor(t) => {
                payloads.push(t);
                Some(payloads.len() - 1)
            }
            Payload::Signature { .. } => None,
        };
        items.push(NativeItem {
            op: env.req.op,
            id: env.req.id,
            submit_us: env.submit_us,
            reply: env.reply,
            row,
            trace: env.req.trace,
            span: env.span_trace,
        });
    }
    let t0 = shared.now_us();
    // Flush-level span context: the first item's trace id represents the
    // flush (its waterfall is the one a flush span belongs to), and the
    // signature label is interned once per flush so spans carry a small
    // integer instead of a string.
    let flush_trace = items.first().and_then(|it| it.span);
    let sig_id = tr.map(|t| t.intern(&key.label()));
    // Per-signature accounting: one flush, one queue-wait observation per
    // item, op counters by kind. Pure atomics — always on.
    sig.flushes.fetch_add(1, Ordering::Relaxed);
    sig.requests.fetch_add(items.len() as u64, Ordering::Relaxed);
    sig.record_stage_traced(Stage::FlushAssembly, t0.saturating_sub(opened_us), flush_trace);
    for it in &items {
        sig.record_stage_traced(Stage::QueueWait, t0.saturating_sub(it.submit_us), it.span);
        let ctr = match it.op {
            RequestOp::Project => &sig.projects,
            RequestOp::Insert => &sig.inserts,
            RequestOp::Query { .. } => &sig.queries,
            RequestOp::Delete { .. } => &sig.deletes,
            _ => continue,
        };
        ctr.fetch_add(1, Ordering::Relaxed);
    }
    if let Some(tr) = tr {
        tr.record(Span {
            stage: "assemble",
            flush: Some(flush_id),
            trace: flush_trace,
            sig: sig_id,
            start_us: opened_us,
            dur_us: t0.saturating_sub(opened_us),
            ..Span::default()
        });
        for it in &items {
            tr.record(Span {
                stage: "queue",
                req: Some(it.id),
                flush: Some(flush_id),
                trace: it.span,
                sig: sig_id,
                start_us: it.submit_us,
                dur_us: t0.saturating_sub(it.submit_us),
                ..Span::default()
            });
        }
    }
    let mut out = shared.workspaces.acquire_buf(payloads.len() * k);
    let mut ws = shared.workspaces.acquire();
    // A failed map draw poisons the whole flush with error replies — but
    // the flush still walks its sequencer turns below, because tickets
    // were already issued and an unadvanced turn would wedge the lane.
    let mut flush_error: Option<String> = None;
    if !payloads.is_empty() {
        // Resolve (and lazily draw) the map only when something actually
        // projects: signature-only flushes (delete/stats) must not
        // materialize a projection map — remote-controlled dims would
        // otherwise grow the registry without bound from tensorless
        // requests.
        match shared.registry.get_or_create(&key) {
            Ok(entry) => {
                let t_p0 = shared.now_us();
                entry.map.project_batch_into(&payloads, &mut out, &mut ws);
                let t_p1 = shared.now_us();
                sig.record_stage_traced(Stage::Project, t_p1.saturating_sub(t_p0), flush_trace);
                if let Some(tr) = tr {
                    tr.record(Span {
                        stage: "project",
                        flush: Some(flush_id),
                        trace: flush_trace,
                        sig: sig_id,
                        start_us: t_p0,
                        dur_us: t_p1.saturating_sub(t_p0),
                        ..Span::default()
                    });
                }
            }
            Err(e) => flush_error = Some(format!("projection map creation failed: {e}")),
        }
    }

    // Index phase (present iff the flush carries index ops, in which case
    // the dispatcher issued a sequencer ticket per touched shard lane).
    // The job runs one pass per ticketed shard, in ascending shard order;
    // within each pass it walks the items in order and applies the ops
    // belonging to that shard, so ops apply strictly in arrival order —
    // a query never observes a mutation that arrived after it, whether
    // the two landed in one flush or different flushes (the lane tickets
    // order the flushes per shard, and same-id mutations always share a
    // shard). Each run of queries uninterrupted *by that shard's
    // mutations* is scored as one batched GEMM on the pooled workspace;
    // per-query results gather across passes through a k-way merge under
    // the same (dist, id) total order the per-shard selects use, which is
    // what keeps sharded answers bit-identical to unsharded ones.
    let mut removed: Vec<Option<bool>> = vec![None; items.len()];
    let mut neighbors: Vec<Option<Vec<Neighbor>>> = (0..items.len()).map(|_| None).collect();
    let mut stats: Vec<Option<IndexStats>> = (0..items.len()).map(|_| None).collect();
    let mut snapshots: Vec<Option<SnapshotReport>> = (0..items.len()).map(|_| None).collect();
    let mut restored: Vec<Option<u64>> = vec![None; items.len()];
    let mut op_errors: Vec<Option<String>> = vec![None; items.len()];
    if let Some(e) = &flush_error {
        for oe in op_errors.iter_mut() {
            *oe = Some(e.clone());
        }
    }
    if let Some((slot, tickets)) = index_turn {
        let nshards = slot.shards();
        let snapshot_dir_set = shared.indexes.snapshot_dir().is_some();
        // Stage every query embedding once, contiguously ([nq, k], query
        // arrival order) in a pooled buffer. A run of queries is always a
        // consecutive ordinal range, so per-lane scoring slices this
        // buffer directly — no re-staging per shard pass.
        let query_items: Vec<usize> = items
            .iter()
            .enumerate()
            .filter(|(_, it)| matches!(it.op, RequestOp::Query { .. }))
            .map(|(i, _)| i)
            .collect();
        let mut qstage = shared.workspaces.acquire_buf(query_items.len() * k);
        let mut topks_all = Vec::with_capacity(query_items.len());
        let mut qord: Vec<usize> = vec![0; items.len()];
        for (qi, &i) in query_items.iter().enumerate() {
            match items[i].row {
                // A query without a staged embedding (the dispatcher
                // rejects tensorless queries, so this is belt-and-braces)
                // degrades to an error reply; its staging slot stays
                // zeroed and the scored result is discarded by the
                // error-reply path.
                Some(r) => {
                    qstage[qi * k..(qi + 1) * k].copy_from_slice(&out[r * k..(r + 1) * k])
                }
                None => {
                    op_errors[i].get_or_insert_with(|| "query payload carried no tensor".into());
                }
            }
            if let RequestOp::Query { k: topk } = items[i].op {
                topks_all.push(topk);
            }
            qord[i] = qi;
        }
        // Off-turn preparation: resolve restore plans (disk reads,
        // checksum verification, re-partition, rebuild) before any lane
        // is held, so a slow disk never stalls serving.
        let mut restore_plans: Vec<Option<Result<RestorePlan, String>>> =
            (0..items.len()).map(|_| None).collect();
        for (i, it) in items.iter().enumerate() {
            if matches!(it.op, RequestOp::Restore) {
                restore_plans[i] = Some(shared.indexes.restore_plan(&slot));
            }
        }
        // Copy-on-write snapshot captures: each pass freezes its shard's
        // live-pairs view at the op's arrival position (a memcpy inside
        // the turn); encoding and disk IO happen after every lane is
        // released, so big-corpus snapshots no longer stall the
        // signature's lanes. `cut_marks` records each lane's noted-
        // mutation watermark at the same position — advanced into the
        // covered watermark only after the write succeeds — plus the
        // lane's WAL seq at the cut (the checkpoint watermark written
        // into the manifest; 0 with the WAL off).
        let mut captures: Vec<Vec<IndexSnapshot>> = (0..items.len()).map(|_| Vec::new()).collect();
        let mut cut_marks: Vec<Vec<(usize, u64, u64)>> =
            (0..items.len()).map(|_| Vec::new()).collect();
        // Periodic snapshot decision, made up front: the captures must
        // happen inside the lane turns, but whether this flush crosses
        // the threshold is only exactly known afterwards — so the
        // trigger uses the mutation upper bound (a delete of an absent id
        // overshoots by firing one flush early, which is harmless for a
        // background durability knob). Capturing requires a ticket on
        // every lane; the dispatcher grants that barrier to threshold-
        // crossing flushes (see `dispatch_native_batch`), so a flush
        // without it simply leaves the trigger armed for a later one.
        let flush_mut_bound = items
            .iter()
            .filter(|it| matches!(it.op, RequestOp::Insert | RequestOp::Delete { .. }))
            .count() as u64;
        let has_explicit_snapshot = items.iter().any(|it| matches!(it.op, RequestOp::Snapshot));
        let barrier_held = tickets.len() == nshards;
        let periodic_due = shared.cfg.snapshot_every_ops > 0
            && snapshot_dir_set
            && !has_explicit_snapshot
            && flush_mut_bound > 0
            && barrier_held
            && slot.pending_mutations() + flush_mut_bound >= shared.cfg.snapshot_every_ops;
        let mut periodic_captures: Vec<IndexSnapshot> = Vec::new();
        let mut periodic_marks: Vec<(usize, u64, u64)> = Vec::new();
        // k-way merge time, accumulated across every scored run of every
        // shard pass (recorded once per flush below).
        let mut merge_us = 0u64;
        for &(s, ticket) in &tickets {
            // Lane wait = request → grant of this shard's sequencer turn;
            // the closure stamps its own entry so the wait/scan split is
            // exact.
            let t_wait0 = shared.now_us();
            let mut t_scan0 = t_wait0;
            slot.run_shard_turn(s, ticket, |index| {
                t_scan0 = shared.now_us();
                // A flush-wide failure (no projection ran, `out` holds
                // zeros) must not mutate or score anything — but the turn
                // itself still runs, releasing the ticket to later
                // flushes.
                if flush_error.is_some() {
                    return;
                }
                let mut pending: Vec<usize> = Vec::new();
                for (i, it) in items.iter().enumerate() {
                    match it.op {
                        RequestOp::Project | RequestOp::Metrics { .. } => {}
                        RequestOp::Query { .. } => pending.push(i),
                        RequestOp::Insert => {
                            if shard_of(it.id, nshards) == s {
                                // No embedding staged (dispatcher rejects
                                // tensorless inserts; defensive) → error
                                // reply, and the mutation is skipped, so
                                // no pending-query flush is needed.
                                let Some(r) = it.row else {
                                    op_errors[i].get_or_insert_with(|| {
                                        "insert payload carried no tensor".into()
                                    });
                                    continue;
                                };
                                score_pending(
                                    index.as_mut(),
                                    &qstage,
                                    &topks_all,
                                    &qord,
                                    &mut pending,
                                    &mut neighbors,
                                    &mut ws,
                                    &mut merge_us,
                                );
                                let row = &out[r * k..(r + 1) * k];
                                // Log-before-apply: an op that cannot be
                                // made durable must not mutate (its reply
                                // carries the error instead of an ack).
                                match slot.wal_append(s, wal::WAL_OP_INSERT, it.id, row) {
                                    Ok(Some(_)) => {
                                        shared.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                                    }
                                    Ok(None) => {}
                                    Err(e) => {
                                        op_errors[i].get_or_insert_with(|| {
                                            format!("wal append failed: {e}")
                                        });
                                        continue;
                                    }
                                }
                                index.insert(it.id, row);
                                slot.note_shard_mutations(s, 1);
                                shared.metrics.index_inserts.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        RequestOp::Delete { target } => {
                            if shard_of(target, nshards) == s {
                                score_pending(
                                    index.as_mut(),
                                    &qstage,
                                    &topks_all,
                                    &qord,
                                    &mut pending,
                                    &mut neighbors,
                                    &mut ws,
                                    &mut merge_us,
                                );
                                // A delete of an absent id still logs (the
                                // replayed remove is the same no-op), so
                                // replay never needs the pre-image.
                                match slot.wal_append(s, wal::WAL_OP_DELETE, target, &[]) {
                                    Ok(Some(_)) => {
                                        shared.metrics.wal_appends.fetch_add(1, Ordering::Relaxed);
                                    }
                                    Ok(None) => {}
                                    Err(e) => {
                                        op_errors[i].get_or_insert_with(|| {
                                            format!("wal append failed: {e}")
                                        });
                                        continue;
                                    }
                                }
                                let hit = index.remove(target);
                                removed[i] = Some(hit);
                                slot.note_shard_mutations(s, hit as u64);
                                shared.metrics.index_deletes.fetch_add(1, Ordering::Relaxed);
                            }
                        }
                        RequestOp::IndexStats => {
                            score_pending(
                                index.as_mut(),
                                &qstage,
                                &topks_all,
                                &qord,
                                &mut pending,
                                &mut neighbors,
                                &mut ws,
                                &mut merge_us,
                            );
                            // Signature-level aggregate, folded shard by
                            // shard (sums mutations/len, max for queries).
                            stats[i] = Some(combine_stats(stats[i].take(), index.stats()));
                        }
                        RequestOp::Snapshot => {
                            // Every lane holds a ticket at this op's
                            // arrival position (epoch barrier), so the
                            // union of the per-shard freezes is a
                            // consistent cut: everything that arrived
                            // before this op is captured, nothing after.
                            score_pending(
                                index.as_mut(),
                                &qstage,
                                &topks_all,
                                &qord,
                                &mut pending,
                                &mut neighbors,
                                &mut ws,
                                &mut merge_us,
                            );
                            if snapshot_dir_set {
                                captures[i].push(IndexSnapshot::capture(
                                    slot.key.encode(),
                                    index.as_ref(),
                                ));
                                cut_marks[i].push((s, slot.shard_noted(s), slot.wal_seq(s)));
                            }
                        }
                        RequestOp::Restore => {
                            score_pending(
                                index.as_mut(),
                                &qstage,
                                &topks_all,
                                &qord,
                                &mut pending,
                                &mut neighbors,
                                &mut ws,
                                &mut merge_us,
                            );
                            // Swap in the pre-built shard; mutations that
                            // arrived earlier in this flush were applied
                            // above and are discarded by the reload, ops
                            // after this item apply to the restored
                            // state. (The *source* was resolved off-turn
                            // before the passes: a snapshot's files land
                            // only after its lanes release, so a restore
                            // pipelined behind a snapshot without
                            // awaiting its reply may resolve the
                            // previous sequence — the snapshot reply is
                            // the read-your-writes barrier.)
                            if let Some(Ok(plan)) = restore_plans[i].as_mut() {
                                if let Some(replacement) = plan.shards[s].take() {
                                    *index = replacement;
                                    // The reload discarded everything
                                    // applied to this lane so far; mark
                                    // it covered at this position.
                                    cut_marks[i].push((s, slot.shard_noted(s), 0));
                                    // The logged tail predates the restored
                                    // snapshot — replaying it would
                                    // resurrect the ops the reload just
                                    // discarded, so the lane's log restarts
                                    // here.
                                    if let Err(e) = slot.wal_reset(s) {
                                        op_errors[i].get_or_insert_with(|| {
                                            format!("wal reset failed: {e}")
                                        });
                                    }
                                }
                            }
                        }
                    }
                }
                score_pending(
                    index.as_mut(),
                    &qstage,
                    &topks_all,
                    &qord,
                    &mut pending,
                    &mut neighbors,
                    &mut ws,
                    &mut merge_us,
                );
                if periodic_due {
                    // End-of-flush consistent cut for the periodic
                    // trigger (the dispatcher granted this flush the
                    // full barrier, so every lane contributes).
                    periodic_captures
                        .push(IndexSnapshot::capture(slot.key.encode(), index.as_ref()));
                    periodic_marks.push((s, slot.shard_noted(s), slot.wal_seq(s)));
                }
            });
            let t_scan1 = shared.now_us();
            sig.record_stage_traced(Stage::LaneWait, t_scan0.saturating_sub(t_wait0), flush_trace);
            sig.record_stage_traced(Stage::IndexScan, t_scan1.saturating_sub(t_scan0), flush_trace);
            if let Some(tr) = tr {
                tr.record(Span {
                    stage: "index",
                    flush: Some(flush_id),
                    shard: Some(s as u32),
                    trace: flush_trace,
                    sig: sig_id,
                    start_us: t_scan0,
                    dur_us: t_scan1.saturating_sub(t_scan0),
                    ..Span::default()
                });
            }
        }
        if !query_items.is_empty() {
            sig.record_stage_traced(Stage::Merge, merge_us, flush_trace);
        }
        // Group commit: one `sync_data` per touched lane per flush (not
        // per op), after every lane's turn released and before any reply
        // goes out — an acked mutation is a durable one under the
        // `flush` policy. On failure, every mutation this flush routed
        // to the failing lane answers with an error instead of a
        // silently-volatile ack.
        if slot.wal_enabled() && flush_error.is_none() {
            let fsync = shared
                .indexes
                .wal_config()
                .map(|c| c.fsync)
                .unwrap_or(WalFsync::Flush);
            let t_f0 = shared.now_us();
            let mut synced = false;
            for &(s, _) in &tickets {
                match slot.wal_commit(s, fsync) {
                    Ok(did) => {
                        if did {
                            synced = true;
                            shared.metrics.wal_fsyncs.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                    Err(e) => {
                        for (i, it) in items.iter().enumerate() {
                            let on_lane = match it.op {
                                RequestOp::Insert => shard_of(it.id, nshards) == s,
                                RequestOp::Delete { target } => shard_of(target, nshards) == s,
                                _ => false,
                            };
                            if on_lane {
                                op_errors[i]
                                    .get_or_insert_with(|| format!("wal fsync failed: {e}"));
                            }
                        }
                    }
                }
            }
            if synced {
                sig.record_stage_traced(
                    Stage::WalFsync,
                    shared.now_us().saturating_sub(t_f0),
                    flush_trace,
                );
            }
        }
        // Every lane is released — serving continues while the frozen
        // captures are encoded and written (the COW half of the design),
        // and the reply metadata below is filled in. On success each
        // cut's recorded per-lane watermarks advance the covered marks:
        // mutations noted during the write (by this flush after the cut
        // position, or by later flushes) sit above the watermark and stay
        // pending toward the next periodic trigger.
        for (i, it) in items.iter().enumerate() {
            if op_errors[i].is_some() {
                // Already failed (flush-wide or per-item): no capture was
                // taken and no restore swap ran, so there is nothing to
                // write or report for this item.
                continue;
            }
            match it.op {
                RequestOp::Snapshot => {
                    if !snapshot_dir_set {
                        op_errors[i] = Some("snapshot failed: no snapshot_dir configured".into());
                        continue;
                    }
                    let t_w0 = shared.now_us();
                    let wal_marks = wal_mark_vec(&slot, nshards, &cut_marks[i]);
                    let write =
                        shared.indexes.write_snapshot_with_marks(&slot, &captures[i], &wal_marks);
                    record_snapshot_write(shared, &sig, flush_id, t_w0, flush_trace, sig_id);
                    match write {
                        Ok(report) => {
                            shared.metrics.index_snapshots.fetch_add(1, Ordering::Relaxed);
                            snapshots[i] = Some(report);
                            cover_cut(&slot, &cut_marks[i]);
                        }
                        Err(e) => op_errors[i] = Some(format!("snapshot failed: {e}")),
                    }
                }
                RequestOp::Restore => {
                    match restore_plans[i]
                        .take()
                        .unwrap_or_else(|| Err("restore plan was never resolved".into()))
                    {
                        Ok(plan) => {
                            shared.metrics.index_restores.fetch_add(1, Ordering::Relaxed);
                            restored[i] = Some(plan.items);
                            for &(s, w, _) in &cut_marks[i] {
                                slot.cover_shard(s, w);
                            }
                        }
                        Err(e) => op_errors[i] = Some(format!("restore failed: {e}")),
                    }
                }
                _ => {}
            }
        }
        if periodic_due && flush_error.is_none() {
            let t_w0 = shared.now_us();
            let wal_marks = wal_mark_vec(&slot, nshards, &periodic_marks);
            let write =
                shared.indexes.write_snapshot_with_marks(&slot, &periodic_captures, &wal_marks);
            record_snapshot_write(shared, &sig, flush_id, t_w0, flush_trace, sig_id);
            match write {
                Ok(_) => {
                    shared.metrics.index_snapshots.fetch_add(1, Ordering::Relaxed);
                    cover_cut(&slot, &periodic_marks);
                }
                Err(e) => eprintln!("[coordinator] periodic snapshot failed: {e}"),
            }
        }
        let nqueries = items
            .iter()
            .filter(|it| matches!(it.op, RequestOp::Query { .. }))
            .count() as u64;
        if nqueries > 0 {
            shared.metrics.index_queries.fetch_add(nqueries, Ordering::Relaxed);
        }
        // Observability: partition imbalance and how many lanes actually
        // overlapped (high-water gauges, like `native_flush_max`).
        shared
            .metrics
            .index_shard_max_skew
            .fetch_max(slot.max_skew(), Ordering::Relaxed);
        shared
            .metrics
            .index_shard_parallel
            .fetch_max(slot.parallel_high_water(), Ordering::Relaxed);
        shared.workspaces.release_buf(qstage);
    }
    shared.workspaces.release(ws);
    let t1 = shared.now_us();
    shared.metrics.native_batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .native_requests
        .fetch_add(items.len() as u64, Ordering::Relaxed);
    for (i, it) in items.into_iter().enumerate() {
        if let Some(e) = op_errors[i].take() {
            shared.metrics.failed.fetch_add(1, Ordering::Relaxed);
            // Failed replies count toward end-to-end latency too.
            shared.metrics.e2e_latency.record(t1.saturating_sub(it.submit_us));
            sig.record_e2e(t1.saturating_sub(it.submit_us), it.span);
            sig.errors.fetch_add(1, Ordering::Relaxed);
            let _ = it.reply.send(Err(e));
            continue;
        }
        shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        shared.metrics.e2e_latency.record(t1.saturating_sub(it.submit_us));
        sig.record_e2e(t1.saturating_sub(it.submit_us), it.span);
        // Per-reply embeddings are exact-sized copies out of the pooled
        // flush buffer: they leave the process inside the response, so
        // pooling them would never recycle anything (the pool covers the
        // buffers that *do* come back — flush `out` and query staging).
        let embedding = match it.row {
            Some(r) => out[r * k..(r + 1) * k].to_vec(),
            None => Vec::new(),
        };
        let resp = ProjectResponse {
            id: it.id,
            embedding,
            neighbors: neighbors[i].take(),
            removed: removed[i],
            index: stats[i].take(),
            snapshot: snapshots[i].take(),
            restored: restored[i],
            metrics: None,
            trace: it.trace,
            path: EnginePath::Native,
            queued_us: t0.saturating_sub(it.submit_us),
            exec_us: t1 - t0,
        };
        let _ = it.reply.send(Ok(resp));
    }
    let t2 = shared.now_us();
    sig.record_stage_traced(Stage::Reply, t2.saturating_sub(t1), flush_trace);
    if let Some(tr) = tr {
        tr.record(Span {
            stage: "reply",
            flush: Some(flush_id),
            trace: flush_trace,
            sig: sig_id,
            start_us: t1,
            dur_us: t2.saturating_sub(t1),
            ..Span::default()
        });
    }
    shared.workspaces.release_buf(out);
}

/// Per-lane WAL watermark vector for a snapshot write: the in-turn
/// `wal_seq` readings recorded at the cut, indexed by lane. Empty with
/// the WAL off, which keeps the manifest byte-identical to the WAL-less
/// format. Snapshot cuts hold the full lane barrier, so every lane has
/// an entry; a lane the cut somehow missed stays at watermark 0 (replay
/// re-applies it — idempotent, never lossy).
fn wal_mark_vec(slot: &SharedIndex, nshards: usize, cut: &[(usize, u64, u64)]) -> Vec<u64> {
    if !slot.wal_enabled() {
        return Vec::new();
    }
    let mut marks = vec![0u64; nshards];
    for &(s, _, m) in cut {
        marks[s] = m;
    }
    marks
}

/// After a snapshot write durably renamed its manifest: advance each
/// lane's covered-mutation watermark (periodic-trigger accounting) and
/// its covered WAL watermark (which truncates fully-covered segments).
/// Truncation failure is a disk-space leak, not a correctness problem —
/// recovery skips covered records — so it logs instead of failing ops.
fn cover_cut(slot: &SharedIndex, cut: &[(usize, u64, u64)]) {
    for &(s, w, m) in cut {
        slot.cover_shard(s, w);
        if let Err(e) = slot.wal_cover(s, m) {
            eprintln!("[coordinator] wal truncation failed: {e}");
        }
    }
}

/// Record one snapshot-file write that started at `t_w0` (stage
/// histogram + optional `snapshot` span — the write happens off-turn, so
/// it gets its own stage instead of inflating `index_scan`).
fn record_snapshot_write(
    shared: &Arc<Shared>,
    sig: &crate::obs::SigMetrics,
    flush_id: u64,
    t_w0: u64,
    flush_trace: Option<u64>,
    sig_id: Option<u32>,
) {
    let t_w1 = shared.now_us();
    sig.record_stage_traced(Stage::SnapshotWrite, t_w1.saturating_sub(t_w0), flush_trace);
    if let Some(tr) = &shared.trace {
        tr.record(Span {
            stage: "snapshot",
            flush: Some(flush_id),
            trace: flush_trace,
            sig: sig_id,
            start_us: t_w0,
            dur_us: t_w1.saturating_sub(t_w0),
            ..Span::default()
        });
    }
}

/// Score the accumulated run of queries (`pending` holds item indices)
/// as one batched GEMM against one shard's current state, merge each
/// query's per-shard results into its gathered top-k, then clear the run.
/// Batching only *runs* preserves arrival-order semantics — a query
/// never observes a mutation that arrived after it — while still
/// amortizing the scoring GEMM across adjacent queries (the common
/// bulk-lookup shape). A run is broken only by mutations belonging to
/// the shard being scored: other shards' mutations cannot change this
/// shard's answers, so the sharded run widths amortize even better than
/// the unsharded ones without changing any result.
///
/// The run's embeddings are a contiguous slice of the flush-wide
/// `qstage` buffer (`qord` maps item index → query ordinal) — staged
/// once per flush, not once per shard pass.
#[allow(clippy::too_many_arguments)]
fn score_pending(
    index: &mut dyn AnnIndex,
    qstage: &[f64],
    topks_all: &[usize],
    qord: &[usize],
    pending: &mut Vec<usize>,
    neighbors: &mut [Option<Vec<Neighbor>>],
    ws: &mut Workspace,
    merge_us: &mut u64,
) {
    if pending.is_empty() {
        return;
    }
    let k = index.dim();
    // A run is always a consecutive ordinal range: every query item
    // between two run breaks is pushed, in item order.
    let start = qord[pending[0]];
    let end = start + pending.len();
    debug_assert_eq!(pending.last().map(|&i| qord[i]), Some(end - 1));
    let qs = &qstage[start * k..end * k];
    let topks = &topks_all[start..end];
    let results = index.query_batch(qs, topks, ws);
    let m0 = Instant::now();
    for ((&i, res), &cap) in pending.iter().zip(results).zip(topks) {
        // Gather: fold this shard's list into the query's accumulated
        // top-k (k-way merge under the (dist, id) total order).
        neighbors[i] = Some(match neighbors[i].take() {
            None => res,
            Some(acc) => crate::index::merge_neighbors(acc, res, cap),
        });
    }
    *merge_us += m0.elapsed().as_micros() as u64;
    pending.clear();
}

fn dispatch_pjrt(shared: &Arc<Shared>, pool: &ThreadPool, artifact: &str, batch: Vec<BatchItem>) {
    let shared = Arc::clone(shared);
    let artifact = artifact.to_string();
    pool.submit(move || {
        if let Err(msg) = run_pjrt_batch(&shared, &artifact, &batch) {
            shared
                .metrics
                .failed
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            let t_err = shared.now_us();
            for item in batch {
                // Failed replies count toward end-to-end latency too.
                shared
                    .metrics
                    .e2e_latency
                    .record(t_err.saturating_sub(item.env.submit_us));
                let _ = item.env.reply.send(Err(msg.clone()));
            }
        }
    });
}

/// Execute one padded batch on the PJRT engine; on success the responses
/// are sent inside (so partial failures never double-reply).
fn run_pjrt_batch(shared: &Arc<Shared>, artifact: &str, batch: &[BatchItem]) -> Result<(), String> {
    let engine = shared.engine.as_ref().ok_or("no PJRT engine attached")?;
    let spec = engine
        .spec(artifact)
        .ok_or_else(|| format!("unknown artifact {artifact}"))?
        .clone();
    let t0 = shared.now_us();
    // Resolve the (shared) projection map for this artifact.
    let dims = spec.input_dims().unwrap_or_else(|| vec![spec.input_dim.unwrap_or(0)]);
    let key = match spec.kind {
        ArtifactKind::Tt => MapKey {
            kind: MapKind::Tt {
                rank: spec.rank.ok_or_else(|| format!("artifact {artifact} missing rank"))?,
            },
            dims,
            k: spec.k,
        },
        ArtifactKind::Cp => MapKey {
            kind: MapKind::Cp {
                rank: spec.rank.ok_or_else(|| format!("artifact {artifact} missing rank"))?,
            },
            dims,
            k: spec.k,
        },
        ArtifactKind::Dense => MapKey { kind: MapKind::Gaussian, dims, k: spec.k },
    };
    let entry = shared
        .registry
        .get_or_create_for_artifact(&key, &spec)
        .map_err(|e| e.to_string())?;

    // Pack inputs and assemble the parameter list in manifest order.
    let inputs: Result<Vec<Vec<f32>>, String> = (|| {
        match (&spec.kind, entry.packed.as_ref()) {
            (ArtifactKind::Tt, Some(PackedParams::Tt(g))) => {
                let (n, d, _r, rt) = spec.tt_meta().map_err(|e| e.to_string())?;
                let xs: Vec<&crate::tensor::TtTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        Payload::Tensor(AnyTensor::Tt(t)) => Ok(t),
                        _ => Err("routed non-TT payload to TT artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let (xf, xm, xl) =
                    pack::pack_tt_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![g.0.clone(), g.1.clone(), g.2.clone(), xf, xm, xl])
            }
            (ArtifactKind::Cp, Some(PackedParams::Cp(a))) => {
                let n = spec.n_modes.ok_or_else(|| "CP artifact missing n_modes".to_string())?;
                let d = spec.dim.ok_or_else(|| "CP artifact missing dim".to_string())?;
                let rt =
                    spec.input_rank.ok_or_else(|| "CP artifact missing input_rank".to_string())?;
                let xs: Vec<&crate::tensor::CpTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        Payload::Tensor(AnyTensor::Cp(t)) => Ok(t),
                        _ => Err("routed non-CP payload to CP artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_cp_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![a.as_ref().clone(), x])
            }
            (ArtifactKind::Dense, Some(PackedParams::Dense(w))) => {
                let dim =
                    spec.input_dim.ok_or_else(|| "dense artifact missing input_dim".to_string())?;
                let xs: Vec<&crate::tensor::DenseTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        Payload::Tensor(AnyTensor::Dense(t)) => Ok(t),
                        _ => Err("routed non-dense payload to dense artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_dense_inputs(&xs, spec.batch, dim).map_err(|e| e.to_string())?;
                Ok(vec![w.as_ref().clone(), x])
            }
            _ => Err("registry entry missing packed parameters".to_string()),
        }
    })();
    let inputs = inputs?;

    let y = engine
        .execute(artifact, &inputs)
        .map_err(|e| e.to_string())?;
    let t1 = shared.now_us();

    shared.metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .pjrt_requests
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    shared
        .metrics
        .padded_slots
        .fetch_add((spec.batch - batch.len()) as u64, Ordering::Relaxed);

    // Split the [B, k] output into per-request rows.
    for (i, item) in batch.iter().enumerate() {
        let row = y[i * spec.k..(i + 1) * spec.k].to_vec();
        shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .e2e_latency
            .record(t1.saturating_sub(item.env.submit_us));
        let resp = ProjectResponse {
            id: item.env.req.id,
            embedding: row,
            neighbors: None,
            removed: None,
            index: None,
            snapshot: None,
            restored: None,
            metrics: None,
            trace: item.env.req.trace,
            path: EnginePath::Pjrt(artifact.to_string()),
            queued_us: t0.saturating_sub(item.env.submit_us),
            exec_us: t1 - t0,
        };
        let _ = item.env.reply.send(Ok(resp));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{CpTensor, DenseTensor, TtTensor};

    fn native_coordinator() -> Coordinator {
        Coordinator::start(
            CoordinatorConfig { workers: 2, default_k: 16, ..Default::default() },
            None,
        )
    }

    #[test]
    fn native_roundtrip_all_formats() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(1);
        let payloads = vec![
            AnyTensor::Tt(TtTensor::random_unit(&[3; 5], 2, &mut rng)),
            AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, &mut rng)),
            AnyTensor::Dense(DenseTensor::random_unit(&[4, 4], &mut rng)),
        ];
        for (i, p) in payloads.into_iter().enumerate() {
            let resp = c.project_blocking(ProjectRequest::new(i as u64, p)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.embedding.len(), 16);
            assert_eq!(resp.path, EnginePath::Native);
            assert!(resp.neighbors.is_none());
        }
        let m = c.metrics();
        assert_eq!(m.submitted, 3);
        assert_eq!(m.completed, 3);
        assert_eq!(m.native_requests, 3);
        c.shutdown();
    }

    #[test]
    fn identical_payload_gets_identical_embedding() {
        // Registry determinism through the full service path.
        let c = native_coordinator();
        let mut rng = Rng::seed_from(2);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let r1 = c
            .project_blocking(ProjectRequest::new(1, AnyTensor::Tt(x.clone())))
            .unwrap();
        let r2 = c
            .project_blocking(ProjectRequest::new(2, AnyTensor::Tt(x)))
            .unwrap();
        assert_eq!(r1.embedding, r2.embedding);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(3);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
                c.submit(ProjectRequest::new(i, AnyTensor::Tt(x)))
            })
            .collect();
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..64).collect::<Vec<u64>>());
        assert_eq!(c.metrics().completed, 64);
        c.shutdown();
    }

    #[test]
    fn native_batching_matches_item_at_a_time_execution() {
        // The batched worker path must produce bit-identical embeddings to
        // a native_max_batch = 1 coordinator with the same master seed.
        let mut rng = Rng::seed_from(6);
        let payloads: Vec<AnyTensor> = (0..24)
            .map(|i| match i % 3 {
                0 => AnyTensor::Dense(DenseTensor::random_unit(&[4, 4], &mut rng)),
                1 => AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng)),
                _ => AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, &mut rng)),
            })
            .collect();
        let run = |native_max_batch: usize| -> Vec<Vec<f64>> {
            let c = Coordinator::start(
                CoordinatorConfig {
                    workers: 2,
                    default_k: 16,
                    native_max_batch,
                    ..Default::default()
                },
                None,
            );
            let rxs: Vec<_> = payloads
                .iter()
                .enumerate()
                .map(|(i, p)| c.submit(ProjectRequest::new(i as u64, p.clone())))
                .collect();
            let out = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().unwrap().embedding)
                .collect();
            let m = c.metrics();
            assert_eq!(m.native_requests, payloads.len() as u64);
            assert!(m.native_batches >= 1);
            c.shutdown();
            out
        };
        let batched = run(8);
        let single = run(1);
        assert_eq!(batched, single);
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(4);
        let rx = {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            c.submit(ProjectRequest::new(9, AnyTensor::Tt(x)))
        };
        c.shutdown();
        // The response must still arrive (drain semantics).
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 9);
    }

    #[test]
    fn index_ops_roundtrip_through_coordinator() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(7);
        let dims = vec![3usize; 4];
        let xs: Vec<TtTensor> = (0..6)
            .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
            .collect();
        for (i, x) in xs.iter().enumerate() {
            let resp = c
                .project_blocking(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
                .unwrap();
            assert_eq!(resp.embedding.len(), 16);
        }
        // Query with an inserted item: it must be its own nearest
        // neighbour at distance 0.
        let resp = c
            .project_blocking(ProjectRequest::query(100, AnyTensor::Tt(xs[2].clone()), 3))
            .unwrap();
        let ns = resp.neighbors.expect("query returns neighbors");
        assert_eq!(ns.len(), 3);
        assert_eq!(ns[0].id, 2);
        assert!(ns[0].dist < 1e-9);
        // Delete it and re-query: it must be gone.
        let resp = c
            .project_blocking(ProjectRequest::delete(101, 2, Format::Tt, dims.clone()))
            .unwrap();
        assert_eq!(resp.removed, Some(true));
        assert!(resp.embedding.is_empty());
        let resp = c
            .project_blocking(ProjectRequest::query(102, AnyTensor::Tt(xs[2].clone()), 3))
            .unwrap();
        let ns = resp.neighbors.expect("query returns neighbors");
        assert!(ns.iter().all(|n| n.id != 2));
        // Stats reflect the history.
        let resp = c
            .project_blocking(ProjectRequest::index_stats(103, Format::Tt, dims))
            .unwrap();
        let s = resp.index.expect("stats returned");
        assert_eq!(s.len, 5);
        assert_eq!(s.inserts, 6);
        assert_eq!(s.deletes, 1);
        assert_eq!(s.queries, 2);
        let m = c.metrics();
        assert_eq!(m.index_inserts, 6);
        assert_eq!(m.index_deletes, 1);
        assert_eq!(m.index_queries, 2);
        c.shutdown();
    }

    #[test]
    fn cross_flush_index_ops_execute_in_arrival_order() {
        // Pipelined insert → delete pairs land in separate single-request
        // flushes on different workers; the per-signature sequencer must
        // keep them in arrival order (without it, a delete racing ahead
        // of its insert reports false and leaks the item).
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 4,
                default_k: 8,
                native_max_batch: 1,
                adaptive_batch: false,
                ..Default::default()
            },
            None,
        );
        let mut rng = Rng::seed_from(11);
        let dims = vec![3usize; 4];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        for round in 0..20u64 {
            let rx1 = c.submit(ProjectRequest::insert(round, AnyTensor::Tt(x.clone())));
            let rx2 = c.submit(ProjectRequest::delete(
                1000 + round,
                round,
                Format::Tt,
                dims.clone(),
            ));
            let r1 = rx1.recv().unwrap().unwrap();
            let r2 = rx2.recv().unwrap().unwrap();
            assert_eq!(r1.id, round);
            assert_eq!(r2.removed, Some(true), "delete must observe the prior insert");
        }
        let resp = c
            .project_blocking(ProjectRequest::index_stats(9999, Format::Tt, dims))
            .unwrap();
        assert_eq!(resp.index.unwrap().len, 0, "every insert was deleted in order");
        c.shutdown();
    }

    #[test]
    fn query_before_delete_sees_item_regardless_of_flush_boundaries() {
        // Arrival-order semantics must not depend on whether a pipelined
        // query → delete pair shares a flush: the query arrived first, so
        // it always observes the item.
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                default_k: 8,
                native_max_batch: 4,
                adaptive_batch: false,
                ..Default::default()
            },
            None,
        );
        let mut rng = Rng::seed_from(12);
        let dims = vec![3usize; 4];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        for round in 0..10u64 {
            c.project_blocking(ProjectRequest::insert(1, AnyTensor::Tt(x.clone())))
                .unwrap();
            let rx_q = c.submit(ProjectRequest::query(100 + round, AnyTensor::Tt(x.clone()), 1));
            let rx_d =
                c.submit(ProjectRequest::delete(200 + round, 1, Format::Tt, dims.clone()));
            let q = rx_q.recv().unwrap().unwrap();
            let d = rx_d.recv().unwrap().unwrap();
            let ns = q.neighbors.unwrap();
            assert_eq!(ns.first().map(|n| n.id), Some(1), "query precedes delete");
            assert_eq!(d.removed, Some(true));
        }
        c.shutdown();
    }

    #[test]
    fn delete_of_absent_item_reports_false() {
        let c = native_coordinator();
        let resp = c
            .project_blocking(ProjectRequest::delete(1, 999, Format::Tt, vec![3; 4]))
            .unwrap();
        assert_eq!(resp.removed, Some(false));
        c.shutdown();
    }

    #[test]
    fn project_with_signature_payload_is_rejected() {
        let c = native_coordinator();
        let req = ProjectRequest {
            id: 5,
            op: RequestOp::Project,
            payload: Payload::Signature { format: Format::Tt, dims: vec![3; 4] },
            trace: None,
        };
        let reply = c.project_blocking(req);
        assert!(reply.is_err());
        assert_eq!(c.metrics().failed, 1);
        c.shutdown();
    }

    #[test]
    fn parallel_flush_split_preserves_results() {
        // One signature, one big burst, several workers: the flush is
        // split into sub-batches but responses must be identical to the
        // single-worker run.
        let mut rng = Rng::seed_from(8);
        let payloads: Vec<AnyTensor> = (0..32)
            .map(|_| AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng)))
            .collect();
        let run = |workers: usize| -> Vec<Vec<f64>> {
            let c = Coordinator::start(
                CoordinatorConfig {
                    workers,
                    default_k: 8,
                    native_max_batch: 32,
                    adaptive_batch: false,
                    ..Default::default()
                },
                None,
            );
            let rxs: Vec<_> = payloads
                .iter()
                .enumerate()
                .map(|(i, p)| c.submit(ProjectRequest::new(i as u64, p.clone())))
                .collect();
            let out = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().unwrap().embedding)
                .collect();
            c.shutdown();
            out
        };
        assert_eq!(run(4), run(1));
    }

    #[test]
    fn sharded_index_ops_match_unsharded_bitwise() {
        // One interleaved insert/query/delete/stats history, replayed
        // sequentially against S ∈ {1, 2, 4}: responses must be
        // bit-identical (the tier-1 sharding contract at the service
        // level).
        type OpOut = (Option<Vec<f64>>, Option<Vec<crate::index::Neighbor>>, Option<bool>);
        let mut rng = Rng::seed_from(21);
        let dims = vec![3usize; 4];
        let xs: Vec<TtTensor> = (0..18)
            .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
            .collect();
        let run = |shards: usize| -> Vec<OpOut> {
            let c = Coordinator::start(
                CoordinatorConfig {
                    workers: 3,
                    default_k: 12,
                    index_shards: shards,
                    ..Default::default()
                },
                None,
            );
            let mut outs = Vec::new();
            for (i, x) in xs.iter().enumerate() {
                let r = c
                    .project_blocking(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
                    .unwrap();
                outs.push((Some(r.embedding), None, None));
            }
            for (i, x) in xs.iter().take(6).enumerate() {
                let r = c
                    .project_blocking(ProjectRequest::query(
                        100 + i as u64,
                        AnyTensor::Tt(x.clone()),
                        5,
                    ))
                    .unwrap();
                outs.push((None, r.neighbors, None));
            }
            for target in [2u64, 9, 400] {
                let r = c
                    .project_blocking(ProjectRequest::delete(
                        200 + target,
                        target,
                        Format::Tt,
                        dims.clone(),
                    ))
                    .unwrap();
                outs.push((None, None, r.removed));
            }
            let r = c
                .project_blocking(ProjectRequest::query(300, AnyTensor::Tt(xs[2].clone()), 4))
                .unwrap();
            outs.push((None, r.neighbors, None));
            let stats = c
                .project_blocking(ProjectRequest::index_stats(301, Format::Tt, dims.clone()))
                .unwrap()
                .index
                .unwrap();
            assert_eq!(stats.len, 16);
            assert_eq!(stats.inserts, 18);
            assert_eq!(stats.deletes, 2, "backend counter counts effective deletes only");
            assert_eq!(stats.queries, 7);
            assert_eq!(stats.shards, shards);
            c.shutdown();
            outs
        };
        let unsharded = run(1);
        assert_eq!(run(2), unsharded, "S=2 must be bit-identical to S=1");
        assert_eq!(run(4), unsharded, "S=4 must be bit-identical to S=1");
    }

    #[test]
    fn sharded_cross_flush_ordering_holds_on_same_id() {
        // The PR 2 ordering test, under sharding: pipelined insert →
        // delete pairs on one id land in separate single-request flushes
        // on different workers; the id's shard lane must keep them in
        // arrival order.
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 4,
                default_k: 8,
                native_max_batch: 1,
                adaptive_batch: false,
                index_shards: 4,
                ..Default::default()
            },
            None,
        );
        let mut rng = Rng::seed_from(13);
        let dims = vec![3usize; 4];
        let x = TtTensor::random_unit(&dims, 2, &mut rng);
        for round in 0..20u64 {
            let rx1 = c.submit(ProjectRequest::insert(round, AnyTensor::Tt(x.clone())));
            let rx2 = c.submit(ProjectRequest::delete(
                1000 + round,
                round,
                Format::Tt,
                dims.clone(),
            ));
            let r1 = rx1.recv().unwrap().unwrap();
            let r2 = rx2.recv().unwrap().unwrap();
            assert_eq!(r1.id, round);
            assert_eq!(r2.removed, Some(true), "delete must observe the prior insert");
        }
        let resp = c
            .project_blocking(ProjectRequest::index_stats(9999, Format::Tt, dims))
            .unwrap();
        assert_eq!(resp.index.unwrap().len, 0, "every insert was deleted in order");
        let m = c.metrics();
        assert_eq!(m.index_inserts, 20);
        assert_eq!(m.index_deletes, 20);
        c.shutdown();
    }

    #[test]
    fn insert_only_flushes_ticket_only_their_shards() {
        // Deterministic lane-independence proof: hold one shard's lane
        // open out of band; an insert hashing to another shard must still
        // complete, while an insert hashing to the held shard stays
        // blocked until release.
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                default_k: 8,
                native_max_batch: 1,
                adaptive_batch: false,
                index_shards: 2,
                ..Default::default()
            },
            None,
        );
        let key = MapKey {
            kind: MapKind::Tt { rank: CoordinatorConfig::default().default_tt_rank },
            dims: vec![3; 4],
            k: 8,
        };
        let slot = c.index_slot(&key);
        assert_eq!(slot.shards(), 2);
        // Ids on each shard under the stable partitioning rule.
        let id_a = (0..).find(|&id| shard_of(id, 2) == 0).unwrap();
        let id_b = (0..).find(|&id| shard_of(id, 2) == 1).unwrap();
        // Hold lane 1's next turn on a helper thread.
        let tickets = slot.issue_tickets(&[1]);
        let (hold_tx, hold_rx) = std::sync::mpsc::channel::<()>();
        let holder = {
            let slot = Arc::clone(&slot);
            let ticket = tickets[0].1;
            std::thread::spawn(move || {
                slot.run_shard_turn(1, ticket, |_| hold_rx.recv().unwrap());
            })
        };
        let mut rng = Rng::seed_from(17);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        // Shard-0 insert completes although lane 1 is held…
        let r = c
            .submit(ProjectRequest::insert(id_a, AnyTensor::Tt(x.clone())))
            .recv_timeout(std::time::Duration::from_secs(20))
            .expect("shard-0 flush must not wait on the held shard-1 lane")
            .unwrap();
        assert_eq!(r.id, id_a);
        // …while a shard-1 insert stays queued behind the held turn…
        let rx_b = c.submit(ProjectRequest::insert(id_b, AnyTensor::Tt(x)));
        assert!(
            rx_b.recv_timeout(std::time::Duration::from_millis(300)).is_err(),
            "shard-1 flush must wait for the held lane"
        );
        // …until the lane is released.
        hold_tx.send(()).unwrap();
        holder.join().unwrap();
        let r = rx_b.recv().unwrap().unwrap();
        assert_eq!(r.id, id_b);
        c.shutdown();
    }

    #[test]
    fn metrics_op_returns_snapshot_with_signature_breakdown() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(31);
        let dims = vec![3usize; 4];
        let xs: Vec<TtTensor> = (0..4)
            .map(|_| TtTensor::random_unit(&dims, 2, &mut rng))
            .collect();
        for (i, x) in xs.iter().enumerate() {
            c.project_blocking(ProjectRequest::insert(i as u64, AnyTensor::Tt(x.clone())))
                .unwrap();
        }
        c.project_blocking(ProjectRequest::query(9, AnyTensor::Tt(xs[0].clone()), 2))
            .unwrap();
        let resp = c.project_blocking(ProjectRequest::metrics(10, false)).unwrap();
        assert!(resp.embedding.is_empty());
        let snap = resp.metrics.expect("metrics op returns a snapshot");
        // The snapshot is taken before the metrics op counts itself.
        assert_eq!(snap.global.submitted, 6);
        assert_eq!(snap.global.completed, 5);
        assert_eq!(snap.global.index_inserts, 4);
        assert_eq!(snap.global.index_queries, 1);
        let sig = snap
            .signatures
            .iter()
            .find(|s| s.signature == "tt-r5/3x3x3x3/k16")
            .expect("per-signature entry under the map label");
        assert_eq!(sig.inserts, 4);
        assert_eq!(sig.queries, 1);
        assert_eq!(sig.requests, 5);
        assert!(sig.flushes >= 1);
        for stage in ["queue_wait", "flush_assembly", "project_gemm", "index_scan", "reply"] {
            assert!(
                sig.stages.iter().any(|st| st.stage == stage && st.count > 0),
                "missing stage histogram {stage}"
            );
        }
        assert!(!snap.trace.enabled, "no --trace-dir configured");
        c.shutdown();
    }

    #[test]
    fn metrics_reset_clears_high_water_gauges() {
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                default_k: 8,
                index_shards: 2,
                ..Default::default()
            },
            None,
        );
        let mut rng = Rng::seed_from(32);
        for i in 0..6u64 {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            c.project_blocking(ProjectRequest::insert(i, AnyTensor::Tt(x)))
                .unwrap();
        }
        let snap = c.project_blocking(ProjectRequest::metrics(100, true)).unwrap().metrics.unwrap();
        assert!(snap.global.index_shard_parallel >= 1, "index passes ran");
        assert_eq!(snap.global.index_shard_parallel_now, 0, "idle at snapshot time");
        // reset=true clears the high-waters AFTER the snapshot above.
        let snap2 =
            c.project_blocking(ProjectRequest::metrics(101, false)).unwrap().metrics.unwrap();
        assert_eq!(snap2.global.index_shard_parallel, 0, "high-water cleared by reset");
        assert_eq!(snap2.global.index_shard_max_skew, 0);
        // Counters survive a reset.
        assert_eq!(snap2.global.index_inserts, 6);
        c.shutdown();
    }

    #[test]
    fn adaptive_batch_reports_flush_target() {
        let c = Coordinator::start(
            CoordinatorConfig {
                workers: 2,
                default_k: 8,
                native_max_batch: 16,
                adaptive_batch: true,
                ..Default::default()
            },
            None,
        );
        let mut rng = Rng::seed_from(9);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let _ = c.project_blocking(ProjectRequest::new(1, AnyTensor::Tt(x)));
        let m = c.metrics();
        assert!((1..=16).contains(&m.native_flush_max));
        c.shutdown();
    }
}
