//! The coordinator server: bounded ingress queue → dispatcher thread →
//! (native worker pool | per-artifact dynamic batchers → PJRT engine).

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{EnginePath, ProjectRequest, ProjectResponse};
use super::router::{RouteTarget, Router};
use super::state::{MapKey, MapKind, PackedParams, ProjectionRegistry};
use crate::runtime::{pack, ArtifactKind, PjrtEngine};
use crate::tensor::AnyTensor;
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads executing projections.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Dynamic-batcher deadline (µs).
    pub max_delay_us: u64,
    /// Master seed for the projection registry.
    pub master_seed: u64,
    /// Map policy for native TT-format requests: TT rank.
    pub default_tt_rank: usize,
    /// Map policy for native CP-format requests: CP rank.
    pub default_cp_rank: usize,
    /// Embedding dimension for native-routed requests.
    pub default_k: usize,
    /// Dense inputs above this size use very sparse RP instead of Gaussian.
    pub dense_gaussian_limit: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_cap: 1024,
            max_delay_us: 2_000,
            master_seed: 0xC0FFEE,
            default_tt_rank: 5,
            default_cp_rank: 25,
            default_k: 64,
            dense_gaussian_limit: 1 << 20,
        }
    }
}

/// Reply type: the response or a failure message.
pub type Reply = Result<ProjectResponse, String>;

struct Envelope {
    req: ProjectRequest,
    submit_us: u64,
    reply: SyncSender<Reply>,
}

struct Shared {
    registry: ProjectionRegistry,
    engine: Option<PjrtEngine>,
    metrics: Metrics,
    cfg: CoordinatorConfig,
    epoch: Instant,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// The coordinator service handle.
pub struct Coordinator {
    shared: Arc<Shared>,
    tx: Option<SyncSender<Envelope>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a coordinator. Pass a loaded [`PjrtEngine`] to enable the
    /// compiled path; with `None` everything runs on the native engine.
    pub fn start(cfg: CoordinatorConfig, engine: Option<PjrtEngine>) -> Self {
        let shared = Arc::new(Shared {
            registry: ProjectionRegistry::new(cfg.master_seed),
            engine,
            metrics: Metrics::new(),
            cfg: cfg.clone(),
            epoch: Instant::now(),
        });
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_cap);
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatcher_loop(shared, rx))
        };
        Self { shared, tx: Some(tx), dispatcher: Some(dispatcher) }
    }

    /// Submit a request; blocks if the ingress queue is full
    /// (backpressure). Returns the channel the response arrives on.
    pub fn submit(&self, req: ProjectRequest) -> Receiver<Reply> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let env = Envelope {
            req,
            submit_us: self.shared.now_us(),
            reply: reply_tx,
        };
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("coordinator shut down")
            .send(env)
            .expect("dispatcher gone");
        reply_rx
    }

    /// Submit and wait for the response.
    pub fn project_blocking(&self, req: ProjectRequest) -> Reply {
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Err("coordinator dropped the request".into()))
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Whether a PJRT engine is attached.
    pub fn has_pjrt(&self) -> bool {
        self.shared.engine.is_some()
    }

    /// Graceful shutdown: drains queued requests, then joins all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Items carried through a PJRT batch.
struct BatchItem {
    env: Envelope,
}

fn dispatcher_loop(shared: Arc<Shared>, rx: Receiver<Envelope>) {
    // Build the routing table from the attached engine's artifacts.
    let mut router = Router::new();
    let mut artifact_batch_cfg: HashMap<String, BatcherConfig> = HashMap::new();
    if let Some(engine) = &shared.engine {
        let mut specs: Vec<_> = engine
            .artifact_names()
            .iter()
            .filter_map(|n| engine.spec(n).cloned())
            .collect();
        // Later registrations shadow earlier ones for identical
        // signatures: put pallas-path artifacts first so their fused
        // (non-pallas) twins win the route. On CPU the interpret-mode
        // pallas lowering is ~20× slower (EXPERIMENTS.md §Perf); on a
        // real TPU the preference would flip.
        specs.sort_by_key(|s| std::cmp::Reverse(s.use_pallas));
        router.register_artifacts(specs.iter());
        for s in &specs {
            artifact_batch_cfg.insert(
                s.name.clone(),
                BatcherConfig { max_batch: s.batch, max_delay_us: shared.cfg.max_delay_us },
            );
        }
    }
    let pool = ThreadPool::new(shared.cfg.workers, shared.cfg.queue_cap);
    let mut batchers: HashMap<String, Batcher<BatchItem>> = HashMap::new();

    loop {
        // Sleep until the nearest batch deadline (or a coarse tick).
        let now = shared.now_us();
        let next_deadline = batchers
            .values()
            .filter_map(|b| b.deadline_us())
            .min()
            .unwrap_or(now + 5_000);
        let wait = Duration::from_micros(next_deadline.saturating_sub(now).max(100));
        match rx.recv_timeout(wait) {
            Ok(env) => {
                match router.route(&env.req.payload) {
                    RouteTarget::Native => {
                        dispatch_native(&shared, &pool, env);
                    }
                    RouteTarget::Pjrt(name) => {
                        let cfg = artifact_batch_cfg[&name];
                        let b = batchers
                            .entry(name.clone())
                            .or_insert_with(|| Batcher::new(cfg));
                        if let Some(batch) = b.push(BatchItem { env }, shared.now_us()) {
                            dispatch_pjrt(&shared, &pool, &name, batch);
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                let now = shared.now_us();
                for (name, b) in batchers.iter_mut() {
                    if let Some(batch) = b.poll(now) {
                        dispatch_pjrt(&shared, &pool, name, batch);
                    }
                }
            }
            Err(RecvTimeoutError::Disconnected) => {
                // Drain: flush every pending batch, then stop.
                for (name, b) in batchers.iter_mut() {
                    if let Some(batch) = b.flush() {
                        dispatch_pjrt(&shared, &pool, name, batch);
                    }
                }
                break;
            }
        }
    }
    // Dropping the pool joins the workers after queued jobs finish.
    drop(pool);
}

/// Map policy for native-path requests.
fn native_map_key(shared: &Shared, payload: &AnyTensor) -> MapKey {
    let cfg = &shared.cfg;
    let dims = payload.dims().to_vec();
    match payload {
        AnyTensor::Tt(_) => MapKey {
            kind: MapKind::Tt { rank: cfg.default_tt_rank },
            dims,
            k: cfg.default_k,
        },
        AnyTensor::Cp(_) => MapKey {
            kind: MapKind::Cp { rank: cfg.default_cp_rank },
            dims,
            k: cfg.default_k,
        },
        AnyTensor::Dense(t) => {
            let kind = if t.numel() <= cfg.dense_gaussian_limit {
                MapKind::Gaussian
            } else {
                MapKind::VerySparse
            };
            MapKey { kind, dims, k: cfg.default_k }
        }
    }
}

fn dispatch_native(shared: &Arc<Shared>, pool: &ThreadPool, env: Envelope) {
    let shared = Arc::clone(shared);
    pool.submit(move || {
        let key = native_map_key(&shared, &env.req.payload);
        let entry = shared.registry.get_or_create(&key);
        let t0 = shared.now_us();
        let embedding = entry.map.project(&env.req.payload);
        let t1 = shared.now_us();
        shared.metrics.native_requests.fetch_add(1, Ordering::Relaxed);
        shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        shared.metrics.e2e_latency.record(t1.saturating_sub(env.submit_us));
        let resp = ProjectResponse {
            id: env.req.id,
            embedding,
            path: EnginePath::Native,
            queued_us: t0.saturating_sub(env.submit_us),
            exec_us: t1 - t0,
        };
        let _ = env.reply.send(Ok(resp));
    });
}

fn dispatch_pjrt(shared: &Arc<Shared>, pool: &ThreadPool, artifact: &str, batch: Vec<BatchItem>) {
    let shared = Arc::clone(shared);
    let artifact = artifact.to_string();
    pool.submit(move || {
        if let Err(msg) = run_pjrt_batch(&shared, &artifact, &batch) {
            shared
                .metrics
                .failed
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            for item in batch {
                let _ = item.env.reply.send(Err(msg.clone()));
            }
        }
    });
}

/// Execute one padded batch on the PJRT engine; on success the responses
/// are sent inside (so partial failures never double-reply).
fn run_pjrt_batch(shared: &Arc<Shared>, artifact: &str, batch: &[BatchItem]) -> Result<(), String> {
    let engine = shared.engine.as_ref().ok_or("no PJRT engine attached")?;
    let spec = engine
        .spec(artifact)
        .ok_or_else(|| format!("unknown artifact {artifact}"))?
        .clone();
    let t0 = shared.now_us();
    // Resolve the (shared) projection map for this artifact.
    let dims = spec.input_dims().unwrap_or_else(|| vec![spec.input_dim.unwrap_or(0)]);
    let key = match spec.kind {
        ArtifactKind::Tt => MapKey {
            kind: MapKind::Tt { rank: spec.rank.unwrap() },
            dims,
            k: spec.k,
        },
        ArtifactKind::Cp => MapKey {
            kind: MapKind::Cp { rank: spec.rank.unwrap() },
            dims,
            k: spec.k,
        },
        ArtifactKind::Dense => MapKey { kind: MapKind::Gaussian, dims, k: spec.k },
    };
    let entry = shared
        .registry
        .get_or_create_for_artifact(&key, &spec)
        .map_err(|e| e.to_string())?;

    // Pack inputs and assemble the parameter list in manifest order.
    let inputs: Result<Vec<Vec<f32>>, String> = (|| {
        match (&spec.kind, entry.packed.as_ref()) {
            (ArtifactKind::Tt, Some(PackedParams::Tt(g))) => {
                let (n, d, _r, rt) = spec.tt_meta().map_err(|e| e.to_string())?;
                let xs: Vec<&crate::tensor::TtTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Tt(t) => Ok(t),
                        _ => Err("routed non-TT payload to TT artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let (xf, xm, xl) =
                    pack::pack_tt_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![g.0.clone(), g.1.clone(), g.2.clone(), xf, xm, xl])
            }
            (ArtifactKind::Cp, Some(PackedParams::Cp(a))) => {
                let n = spec.n_modes.unwrap();
                let d = spec.dim.unwrap();
                let rt = spec.input_rank.unwrap();
                let xs: Vec<&crate::tensor::CpTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Cp(t) => Ok(t),
                        _ => Err("routed non-CP payload to CP artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_cp_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![a.as_ref().clone(), x])
            }
            (ArtifactKind::Dense, Some(PackedParams::Dense(w))) => {
                let dim = spec.input_dim.unwrap();
                let xs: Vec<&crate::tensor::DenseTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Dense(t) => Ok(t),
                        _ => Err("routed non-dense payload to dense artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_dense_inputs(&xs, spec.batch, dim).map_err(|e| e.to_string())?;
                Ok(vec![w.as_ref().clone(), x])
            }
            _ => Err("registry entry missing packed parameters".to_string()),
        }
    })();
    let inputs = inputs?;

    let y = engine
        .execute(artifact, &inputs)
        .map_err(|e| e.to_string())?;
    let t1 = shared.now_us();

    shared.metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .pjrt_requests
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    shared
        .metrics
        .padded_slots
        .fetch_add((spec.batch - batch.len()) as u64, Ordering::Relaxed);

    // Split the [B, k] output into per-request rows.
    for (i, item) in batch.iter().enumerate() {
        let row = y[i * spec.k..(i + 1) * spec.k].to_vec();
        shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .e2e_latency
            .record(t1.saturating_sub(item.env.submit_us));
        let resp = ProjectResponse {
            id: item.env.req.id,
            embedding: row,
            path: EnginePath::Pjrt(artifact.to_string()),
            queued_us: t0.saturating_sub(item.env.submit_us),
            exec_us: t1 - t0,
        };
        let _ = item.env.reply.send(Ok(resp));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{CpTensor, DenseTensor, TtTensor};

    fn native_coordinator() -> Coordinator {
        Coordinator::start(
            CoordinatorConfig { workers: 2, default_k: 16, ..Default::default() },
            None,
        )
    }

    #[test]
    fn native_roundtrip_all_formats() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(1);
        let payloads = vec![
            AnyTensor::Tt(TtTensor::random_unit(&[3; 5], 2, &mut rng)),
            AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, &mut rng)),
            AnyTensor::Dense(DenseTensor::random_unit(&[4, 4], &mut rng)),
        ];
        for (i, p) in payloads.into_iter().enumerate() {
            let resp = c.project_blocking(ProjectRequest::new(i as u64, p)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.embedding.len(), 16);
            assert_eq!(resp.path, EnginePath::Native);
        }
        let m = c.metrics();
        assert_eq!(m.submitted, 3);
        assert_eq!(m.completed, 3);
        assert_eq!(m.native_requests, 3);
        c.shutdown();
    }

    #[test]
    fn identical_payload_gets_identical_embedding() {
        // Registry determinism through the full service path.
        let c = native_coordinator();
        let mut rng = Rng::seed_from(2);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let r1 = c
            .project_blocking(ProjectRequest::new(1, AnyTensor::Tt(x.clone())))
            .unwrap();
        let r2 = c
            .project_blocking(ProjectRequest::new(2, AnyTensor::Tt(x)))
            .unwrap();
        assert_eq!(r1.embedding, r2.embedding);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(3);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
                c.submit(ProjectRequest::new(i, AnyTensor::Tt(x)))
            })
            .collect();
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..64).collect::<Vec<u64>>());
        assert_eq!(c.metrics().completed, 64);
        c.shutdown();
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(4);
        let rx = {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            c.submit(ProjectRequest::new(9, AnyTensor::Tt(x)))
        };
        c.shutdown();
        // The response must still arrive (drain semantics).
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 9);
    }
}
