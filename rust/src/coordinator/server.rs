//! The coordinator server: bounded ingress queue → dispatcher thread →
//! (per-map native dynamic batchers → worker pool | per-artifact dynamic
//! batchers → PJRT engine).
//!
//! Both execution paths are batch-first: the native route accumulates
//! requests per map signature exactly like the PJRT route does per
//! artifact, and a flushed batch of `B` requests executes as **one**
//! [`crate::projections::Projection::project_batch_into`] call on a
//! pooled [`crate::projections::Workspace`] — there is no per-item
//! `project` call anywhere in the worker loop.

use super::batcher::{Batcher, BatcherConfig};
use super::metrics::Metrics;
use super::request::{EnginePath, ProjectRequest, ProjectResponse};
use super::router::{RouteTarget, Router};
use super::state::{MapKey, MapKind, PackedParams, ProjectionRegistry, WorkspacePool};
use crate::runtime::{pack, ArtifactKind, PjrtEngine};
use crate::tensor::AnyTensor;
use crate::util::threadpool::ThreadPool;
use std::collections::HashMap;
use std::sync::atomic::Ordering;
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Coordinator configuration.
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Worker threads executing projections.
    pub workers: usize,
    /// Ingress queue capacity (backpressure bound).
    pub queue_cap: usize,
    /// Dynamic-batcher deadline (µs) — applies to both the PJRT and the
    /// native batchers.
    pub max_delay_us: u64,
    /// Native-path batch size: requests sharing a map signature accumulate
    /// up to this count (or the deadline) and execute as one
    /// `project_batch_into` call. `1` restores item-at-a-time dispatch.
    pub native_max_batch: usize,
    /// Master seed for the projection registry.
    pub master_seed: u64,
    /// Map policy for native TT-format requests: TT rank.
    pub default_tt_rank: usize,
    /// Map policy for native CP-format requests: CP rank.
    pub default_cp_rank: usize,
    /// Embedding dimension for native-routed requests.
    pub default_k: usize,
    /// Dense inputs above this size use very sparse RP instead of Gaussian.
    pub dense_gaussian_limit: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        Self {
            workers: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            queue_cap: 1024,
            max_delay_us: 2_000,
            native_max_batch: 16,
            master_seed: 0xC0FFEE,
            default_tt_rank: 5,
            default_cp_rank: 25,
            default_k: 64,
            dense_gaussian_limit: 1 << 20,
        }
    }
}

/// Reply type: the response or a failure message.
pub type Reply = Result<ProjectResponse, String>;

struct Envelope {
    req: ProjectRequest,
    submit_us: u64,
    reply: SyncSender<Reply>,
}

struct Shared {
    registry: ProjectionRegistry,
    engine: Option<PjrtEngine>,
    metrics: Metrics,
    workspaces: WorkspacePool,
    cfg: CoordinatorConfig,
    epoch: Instant,
}

impl Shared {
    fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }
}

/// The coordinator service handle.
pub struct Coordinator {
    shared: Arc<Shared>,
    tx: Option<SyncSender<Envelope>>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start a coordinator. Pass a loaded [`PjrtEngine`] to enable the
    /// compiled path; with `None` everything runs on the native engine.
    pub fn start(cfg: CoordinatorConfig, engine: Option<PjrtEngine>) -> Self {
        let shared = Arc::new(Shared {
            registry: ProjectionRegistry::new(cfg.master_seed),
            engine,
            metrics: Metrics::new(),
            workspaces: WorkspacePool::new(),
            cfg: cfg.clone(),
            epoch: Instant::now(),
        });
        let (tx, rx) = sync_channel::<Envelope>(cfg.queue_cap);
        let dispatcher = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || dispatcher_loop(shared, rx))
        };
        Self { shared, tx: Some(tx), dispatcher: Some(dispatcher) }
    }

    /// Submit a request; blocks if the ingress queue is full
    /// (backpressure). Returns the channel the response arrives on.
    pub fn submit(&self, req: ProjectRequest) -> Receiver<Reply> {
        let (reply_tx, reply_rx) = sync_channel(1);
        let env = Envelope {
            req,
            submit_us: self.shared.now_us(),
            reply: reply_tx,
        };
        self.shared.metrics.submitted.fetch_add(1, Ordering::Relaxed);
        self.tx
            .as_ref()
            .expect("coordinator shut down")
            .send(env)
            .expect("dispatcher gone");
        reply_rx
    }

    /// Submit and wait for the response.
    pub fn project_blocking(&self, req: ProjectRequest) -> Reply {
        self.submit(req)
            .recv()
            .unwrap_or_else(|_| Err("coordinator dropped the request".into()))
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Whether a PJRT engine is attached.
    pub fn has_pjrt(&self) -> bool {
        self.shared.engine.is_some()
    }

    /// Graceful shutdown: drains queued requests, then joins all threads.
    pub fn shutdown(mut self) {
        self.shutdown_inner();
    }

    fn shutdown_inner(&mut self) {
        drop(self.tx.take());
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown_inner();
    }
}

/// Items carried through a PJRT batch.
struct BatchItem {
    env: Envelope,
}

fn dispatcher_loop(shared: Arc<Shared>, rx: Receiver<Envelope>) {
    // Build the routing table from the attached engine's artifacts.
    let mut router = Router::new();
    let mut artifact_batch_cfg: HashMap<String, BatcherConfig> = HashMap::new();
    if let Some(engine) = &shared.engine {
        let mut specs: Vec<_> = engine
            .artifact_names()
            .iter()
            .filter_map(|n| engine.spec(n).cloned())
            .collect();
        // Later registrations shadow earlier ones for identical
        // signatures: put pallas-path artifacts first so their fused
        // (non-pallas) twins win the route. On CPU the interpret-mode
        // pallas lowering is ~20× slower (EXPERIMENTS.md §Perf); on a
        // real TPU the preference would flip.
        specs.sort_by_key(|s| std::cmp::Reverse(s.use_pallas));
        router.register_artifacts(specs.iter());
        for s in &specs {
            artifact_batch_cfg.insert(
                s.name.clone(),
                BatcherConfig { max_batch: s.batch, max_delay_us: shared.cfg.max_delay_us },
            );
        }
    }
    let pool = ThreadPool::new(shared.cfg.workers, shared.cfg.queue_cap);
    let mut batchers: HashMap<String, Batcher<BatchItem>> = HashMap::new();
    // Native requests batch per map signature, mirroring the per-artifact
    // PJRT batchers: size native_max_batch or the shared deadline.
    let native_cfg = BatcherConfig {
        max_batch: shared.cfg.native_max_batch.max(1),
        max_delay_us: shared.cfg.max_delay_us,
    };
    let mut native_batchers: HashMap<MapKey, Batcher<Envelope>> = HashMap::new();

    loop {
        // Sleep until the nearest batch deadline (or a coarse tick).
        let now = shared.now_us();
        let next_deadline = batchers
            .values()
            .filter_map(|b| b.deadline_us())
            .chain(native_batchers.values().filter_map(|b| b.deadline_us()))
            .min()
            .unwrap_or(now + 5_000);
        let wait = Duration::from_micros(next_deadline.saturating_sub(now).max(100));
        match rx.recv_timeout(wait) {
            Ok(env) => {
                match router.route(&env.req.payload) {
                    RouteTarget::Native => {
                        let key = native_map_key(&shared, &env.req.payload);
                        // Clone the key only on first sight of a signature;
                        // the steady-state path just borrows it.
                        if !native_batchers.contains_key(&key) {
                            native_batchers.insert(key.clone(), Batcher::new(native_cfg));
                        }
                        let b = native_batchers.get_mut(&key).expect("just inserted");
                        if let Some(batch) = b.push(env, shared.now_us()) {
                            dispatch_native_batch(&shared, &pool, key, batch);
                        }
                    }
                    RouteTarget::Pjrt(name) => {
                        let cfg = artifact_batch_cfg[&name];
                        let b = batchers
                            .entry(name.clone())
                            .or_insert_with(|| Batcher::new(cfg));
                        if let Some(batch) = b.push(BatchItem { env }, shared.now_us()) {
                            dispatch_pjrt(&shared, &pool, &name, batch);
                        }
                    }
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            Err(RecvTimeoutError::Disconnected) => {
                // Drain: flush every pending batch, then stop.
                for (name, b) in batchers.iter_mut() {
                    if let Some(batch) = b.flush() {
                        dispatch_pjrt(&shared, &pool, name, batch);
                    }
                }
                for (key, b) in native_batchers.iter_mut() {
                    if let Some(batch) = b.flush() {
                        dispatch_native_batch(&shared, &pool, key.clone(), batch);
                    }
                }
                break;
            }
        }
        // Deadline sweep on every iteration — arrivals included — so a
        // sustained request stream (recv_timeout always returning Ok
        // before the timeout fires) cannot starve an expired batch past
        // its max_delay_us deadline.
        let now = shared.now_us();
        for (name, b) in batchers.iter_mut() {
            if let Some(batch) = b.poll(now) {
                dispatch_pjrt(&shared, &pool, name, batch);
            }
        }
        for (key, b) in native_batchers.iter_mut() {
            if let Some(batch) = b.poll(now) {
                dispatch_native_batch(&shared, &pool, key.clone(), batch);
            }
        }
        // MapKey dims come verbatim from (possibly remote) payloads, so
        // distinct signatures are unbounded over a server's lifetime;
        // evict idle batchers past a high-water mark to bound both the
        // map's memory and the sweep above.
        const MAX_IDLE_NATIVE_BATCHERS: usize = 1024;
        if native_batchers.len() > MAX_IDLE_NATIVE_BATCHERS {
            native_batchers.retain(|_, b| !b.is_empty());
        }
    }
    // Dropping the pool joins the workers after queued jobs finish.
    drop(pool);
}

/// Map policy for native-path requests.
fn native_map_key(shared: &Shared, payload: &AnyTensor) -> MapKey {
    let cfg = &shared.cfg;
    let dims = payload.dims().to_vec();
    match payload {
        AnyTensor::Tt(_) => MapKey {
            kind: MapKind::Tt { rank: cfg.default_tt_rank },
            dims,
            k: cfg.default_k,
        },
        AnyTensor::Cp(_) => MapKey {
            kind: MapKind::Cp { rank: cfg.default_cp_rank },
            dims,
            k: cfg.default_k,
        },
        AnyTensor::Dense(t) => {
            let kind = if t.numel() <= cfg.dense_gaussian_limit {
                MapKind::Gaussian
            } else {
                MapKind::VerySparse
            };
            MapKey { kind, dims, k: cfg.default_k }
        }
    }
}

/// Execute one flushed native batch: resolve the shared map, run the
/// whole batch through a single `project_batch_into` call on a pooled
/// workspace, then split the `[B, k]` output into per-request replies.
fn dispatch_native_batch(
    shared: &Arc<Shared>,
    pool: &ThreadPool,
    key: MapKey,
    batch: Vec<Envelope>,
) {
    let shared = Arc::clone(shared);
    pool.submit(move || {
        let entry = shared.registry.get_or_create(&key);
        let k = key.k;
        let b = batch.len();
        // Split payloads from reply metadata: `project_batch_into` takes
        // the payload slice by reference, so no tensor is cloned.
        let mut payloads = Vec::with_capacity(b);
        let mut meta = Vec::with_capacity(b);
        for env in batch {
            payloads.push(env.req.payload);
            meta.push((env.req.id, env.submit_us, env.reply));
        }
        let mut out = vec![0.0; b * k];
        let t0 = shared.now_us();
        let mut ws = shared.workspaces.acquire();
        entry.map.project_batch_into(&payloads, &mut out, &mut ws);
        shared.workspaces.release(ws);
        let t1 = shared.now_us();
        shared.metrics.native_batches.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .native_requests
            .fetch_add(b as u64, Ordering::Relaxed);
        for (i, (id, submit_us, reply)) in meta.into_iter().enumerate() {
            shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
            shared.metrics.e2e_latency.record(t1.saturating_sub(submit_us));
            let resp = ProjectResponse {
                id,
                embedding: out[i * k..(i + 1) * k].to_vec(),
                path: EnginePath::Native,
                queued_us: t0.saturating_sub(submit_us),
                exec_us: t1 - t0,
            };
            let _ = reply.send(Ok(resp));
        }
    });
}

fn dispatch_pjrt(shared: &Arc<Shared>, pool: &ThreadPool, artifact: &str, batch: Vec<BatchItem>) {
    let shared = Arc::clone(shared);
    let artifact = artifact.to_string();
    pool.submit(move || {
        if let Err(msg) = run_pjrt_batch(&shared, &artifact, &batch) {
            shared
                .metrics
                .failed
                .fetch_add(batch.len() as u64, Ordering::Relaxed);
            for item in batch {
                let _ = item.env.reply.send(Err(msg.clone()));
            }
        }
    });
}

/// Execute one padded batch on the PJRT engine; on success the responses
/// are sent inside (so partial failures never double-reply).
fn run_pjrt_batch(shared: &Arc<Shared>, artifact: &str, batch: &[BatchItem]) -> Result<(), String> {
    let engine = shared.engine.as_ref().ok_or("no PJRT engine attached")?;
    let spec = engine
        .spec(artifact)
        .ok_or_else(|| format!("unknown artifact {artifact}"))?
        .clone();
    let t0 = shared.now_us();
    // Resolve the (shared) projection map for this artifact.
    let dims = spec.input_dims().unwrap_or_else(|| vec![spec.input_dim.unwrap_or(0)]);
    let key = match spec.kind {
        ArtifactKind::Tt => MapKey {
            kind: MapKind::Tt { rank: spec.rank.unwrap() },
            dims,
            k: spec.k,
        },
        ArtifactKind::Cp => MapKey {
            kind: MapKind::Cp { rank: spec.rank.unwrap() },
            dims,
            k: spec.k,
        },
        ArtifactKind::Dense => MapKey { kind: MapKind::Gaussian, dims, k: spec.k },
    };
    let entry = shared
        .registry
        .get_or_create_for_artifact(&key, &spec)
        .map_err(|e| e.to_string())?;

    // Pack inputs and assemble the parameter list in manifest order.
    let inputs: Result<Vec<Vec<f32>>, String> = (|| {
        match (&spec.kind, entry.packed.as_ref()) {
            (ArtifactKind::Tt, Some(PackedParams::Tt(g))) => {
                let (n, d, _r, rt) = spec.tt_meta().map_err(|e| e.to_string())?;
                let xs: Vec<&crate::tensor::TtTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Tt(t) => Ok(t),
                        _ => Err("routed non-TT payload to TT artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let (xf, xm, xl) =
                    pack::pack_tt_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![g.0.clone(), g.1.clone(), g.2.clone(), xf, xm, xl])
            }
            (ArtifactKind::Cp, Some(PackedParams::Cp(a))) => {
                let n = spec.n_modes.unwrap();
                let d = spec.dim.unwrap();
                let rt = spec.input_rank.unwrap();
                let xs: Vec<&crate::tensor::CpTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Cp(t) => Ok(t),
                        _ => Err("routed non-CP payload to CP artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_cp_inputs(&xs, spec.batch, n, d, rt).map_err(|e| e.to_string())?;
                Ok(vec![a.as_ref().clone(), x])
            }
            (ArtifactKind::Dense, Some(PackedParams::Dense(w))) => {
                let dim = spec.input_dim.unwrap();
                let xs: Vec<&crate::tensor::DenseTensor> = batch
                    .iter()
                    .map(|item| match &item.env.req.payload {
                        AnyTensor::Dense(t) => Ok(t),
                        _ => Err("routed non-dense payload to dense artifact".to_string()),
                    })
                    .collect::<Result<_, _>>()?;
                let x = pack::pack_dense_inputs(&xs, spec.batch, dim).map_err(|e| e.to_string())?;
                Ok(vec![w.as_ref().clone(), x])
            }
            _ => Err("registry entry missing packed parameters".to_string()),
        }
    })();
    let inputs = inputs?;

    let y = engine
        .execute(artifact, &inputs)
        .map_err(|e| e.to_string())?;
    let t1 = shared.now_us();

    shared.metrics.pjrt_batches.fetch_add(1, Ordering::Relaxed);
    shared
        .metrics
        .pjrt_requests
        .fetch_add(batch.len() as u64, Ordering::Relaxed);
    shared
        .metrics
        .padded_slots
        .fetch_add((spec.batch - batch.len()) as u64, Ordering::Relaxed);

    // Split the [B, k] output into per-request rows.
    for (i, item) in batch.iter().enumerate() {
        let row = y[i * spec.k..(i + 1) * spec.k].to_vec();
        shared.metrics.completed.fetch_add(1, Ordering::Relaxed);
        shared
            .metrics
            .e2e_latency
            .record(t1.saturating_sub(item.env.submit_us));
        let resp = ProjectResponse {
            id: item.env.req.id,
            embedding: row,
            path: EnginePath::Pjrt(artifact.to_string()),
            queued_us: t0.saturating_sub(item.env.submit_us),
            exec_us: t1 - t0,
        };
        let _ = item.env.reply.send(Ok(resp));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{CpTensor, DenseTensor, TtTensor};

    fn native_coordinator() -> Coordinator {
        Coordinator::start(
            CoordinatorConfig { workers: 2, default_k: 16, ..Default::default() },
            None,
        )
    }

    #[test]
    fn native_roundtrip_all_formats() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(1);
        let payloads = vec![
            AnyTensor::Tt(TtTensor::random_unit(&[3; 5], 2, &mut rng)),
            AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, &mut rng)),
            AnyTensor::Dense(DenseTensor::random_unit(&[4, 4], &mut rng)),
        ];
        for (i, p) in payloads.into_iter().enumerate() {
            let resp = c.project_blocking(ProjectRequest::new(i as u64, p)).unwrap();
            assert_eq!(resp.id, i as u64);
            assert_eq!(resp.embedding.len(), 16);
            assert_eq!(resp.path, EnginePath::Native);
        }
        let m = c.metrics();
        assert_eq!(m.submitted, 3);
        assert_eq!(m.completed, 3);
        assert_eq!(m.native_requests, 3);
        c.shutdown();
    }

    #[test]
    fn identical_payload_gets_identical_embedding() {
        // Registry determinism through the full service path.
        let c = native_coordinator();
        let mut rng = Rng::seed_from(2);
        let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
        let r1 = c
            .project_blocking(ProjectRequest::new(1, AnyTensor::Tt(x.clone())))
            .unwrap();
        let r2 = c
            .project_blocking(ProjectRequest::new(2, AnyTensor::Tt(x)))
            .unwrap();
        assert_eq!(r1.embedding, r2.embedding);
        c.shutdown();
    }

    #[test]
    fn many_concurrent_requests_all_answered() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(3);
        let rxs: Vec<_> = (0..64)
            .map(|i| {
                let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
                c.submit(ProjectRequest::new(i, AnyTensor::Tt(x)))
            })
            .collect();
        let mut ids: Vec<u64> = rxs
            .into_iter()
            .map(|rx| rx.recv().unwrap().unwrap().id)
            .collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..64).collect::<Vec<u64>>());
        assert_eq!(c.metrics().completed, 64);
        c.shutdown();
    }

    #[test]
    fn native_batching_matches_item_at_a_time_execution() {
        // The batched worker path must produce bit-identical embeddings to
        // a native_max_batch = 1 coordinator with the same master seed.
        let mut rng = Rng::seed_from(6);
        let payloads: Vec<AnyTensor> = (0..24)
            .map(|i| match i % 3 {
                0 => AnyTensor::Dense(DenseTensor::random_unit(&[4, 4], &mut rng)),
                1 => AnyTensor::Tt(TtTensor::random_unit(&[3; 4], 2, &mut rng)),
                _ => AnyTensor::Cp(CpTensor::random_unit(&[3; 4], 2, &mut rng)),
            })
            .collect();
        let run = |native_max_batch: usize| -> Vec<Vec<f64>> {
            let c = Coordinator::start(
                CoordinatorConfig {
                    workers: 2,
                    default_k: 16,
                    native_max_batch,
                    ..Default::default()
                },
                None,
            );
            let rxs: Vec<_> = payloads
                .iter()
                .enumerate()
                .map(|(i, p)| c.submit(ProjectRequest::new(i as u64, p.clone())))
                .collect();
            let out = rxs
                .into_iter()
                .map(|rx| rx.recv().unwrap().unwrap().embedding)
                .collect();
            let m = c.metrics();
            assert_eq!(m.native_requests, payloads.len() as u64);
            assert!(m.native_batches >= 1);
            c.shutdown();
            out
        };
        let batched = run(8);
        let single = run(1);
        assert_eq!(batched, single);
    }

    #[test]
    fn shutdown_drains_pending() {
        let c = native_coordinator();
        let mut rng = Rng::seed_from(4);
        let rx = {
            let x = TtTensor::random_unit(&[3; 4], 2, &mut rng);
            c.submit(ProjectRequest::new(9, AnyTensor::Tt(x)))
        };
        c.shutdown();
        // The response must still arrive (drain semantics).
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.id, 9);
    }
}
