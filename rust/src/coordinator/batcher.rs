//! Dynamic batcher: accumulate requests for one artifact signature until
//! the compiled batch size is reached or the oldest request's deadline
//! expires — the classic serving trade-off between padding waste and
//! queueing latency.
//!
//! Time is passed in explicitly (microsecond ticks) so the policy is
//! deterministic and property-testable without sleeping.

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Flush as soon as this many items are pending (the artifact's
    /// compiled batch size `B`).
    pub max_batch: usize,
    /// Flush when the oldest pending item has waited this long (µs).
    pub max_delay_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_delay_us: 2_000 }
    }
}

/// A size-or-deadline batcher over items of type `T`.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<T>,
    /// Arrival tick of the oldest pending item.
    oldest_us: Option<u64>,
}

impl<T> Batcher<T> {
    /// New empty batcher.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { cfg, pending: Vec::with_capacity(cfg.max_batch), oldest_us: None }
    }

    /// Number of pending items.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Add an item at time `now_us`; returns a full batch if the size
    /// threshold is reached.
    pub fn push(&mut self, item: T, now_us: u64) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest_us = Some(now_us);
        }
        self.pending.push(item);
        if self.pending.len() >= self.cfg.max_batch {
            return self.flush();
        }
        None
    }

    /// Flush if the oldest item's deadline has expired.
    pub fn poll(&mut self, now_us: u64) -> Option<Vec<T>> {
        match self.oldest_us {
            Some(t0) if now_us.saturating_sub(t0) >= self.cfg.max_delay_us => self.flush(),
            _ => None,
        }
    }

    /// Tick at which the current batch must flush (for dispatcher sleeps).
    pub fn deadline_us(&self) -> Option<u64> {
        self.oldest_us.map(|t0| t0 + self.cfg.max_delay_us)
    }

    /// Unconditionally take the pending batch.
    pub fn flush(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            return None;
        }
        self.oldest_us = None;
        Some(std::mem::take(&mut self.pending))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_delay_us: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_delay_us }
    }

    #[test]
    fn flushes_on_size() {
        let mut b = Batcher::new(cfg(3, 1_000));
        assert!(b.push(1, 0).is_none());
        assert!(b.push(2, 10).is_none());
        let batch = b.push(3, 20).expect("full batch");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(cfg(10, 500));
        b.push("a", 100);
        assert!(b.poll(400).is_none(), "deadline not reached");
        let batch = b.poll(600).expect("deadline flush");
        assert_eq!(batch, vec!["a"]);
        assert!(b.poll(10_000).is_none(), "nothing left");
    }

    #[test]
    fn deadline_tracks_oldest_item() {
        let mut b = Batcher::new(cfg(10, 500));
        b.push(1, 100);
        b.push(2, 450);
        assert_eq!(b.deadline_us(), Some(600));
        let batch = b.poll(601).unwrap();
        assert_eq!(batch.len(), 2);
        // After a flush the next push restarts the clock.
        b.push(3, 700);
        assert_eq!(b.deadline_us(), Some(1_200));
    }

    #[test]
    fn manual_flush_drains() {
        let mut b = Batcher::new(cfg(10, 500));
        assert!(b.flush().is_none());
        b.push(1, 0);
        b.push(2, 1);
        assert_eq!(b.flush().unwrap(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn batch_size_one_flushes_immediately() {
        let mut b = Batcher::new(cfg(1, 1_000_000));
        assert_eq!(b.push(42, 0).unwrap(), vec![42]);
    }
}
