//! Dynamic batcher: accumulate requests for one artifact signature until
//! the compiled batch size is reached or the oldest request's deadline
//! expires — the classic serving trade-off between padding waste and
//! queueing latency.
//!
//! Time is passed in explicitly (microsecond ticks) so the policy is
//! deterministic and property-testable without sleeping.

/// Batching policy knobs.
#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    /// Flush as soon as this many items are pending (the artifact's
    /// compiled batch size `B`).
    pub max_batch: usize,
    /// Flush when the oldest pending item has waited this long (µs).
    pub max_delay_us: u64,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        Self { max_batch: 8, max_delay_us: 2_000 }
    }
}

/// A size-or-deadline batcher over items of type `T`.
#[derive(Debug)]
pub struct Batcher<T> {
    cfg: BatcherConfig,
    pending: Vec<T>,
    /// Arrival tick of the oldest pending item.
    oldest_us: Option<u64>,
}

impl<T> Batcher<T> {
    /// New empty batcher.
    pub fn new(cfg: BatcherConfig) -> Self {
        assert!(cfg.max_batch >= 1);
        Self { cfg, pending: Vec::with_capacity(cfg.max_batch), oldest_us: None }
    }

    /// Number of pending items.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// True when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// Current size threshold.
    pub fn max_batch(&self) -> usize {
        self.cfg.max_batch
    }

    /// Retarget the size threshold (adaptive batching), clamped to ≥ 1.
    /// Takes effect from the next push: if the new bound is at or below
    /// the pending count, the next push flushes immediately.
    pub fn set_max_batch(&mut self, max_batch: usize) {
        self.cfg.max_batch = max_batch.max(1);
    }

    /// Add an item at time `now_us`; returns a full batch if the size
    /// threshold is reached.
    pub fn push(&mut self, item: T, now_us: u64) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            self.oldest_us = Some(now_us);
        }
        self.pending.push(item);
        if self.pending.len() >= self.cfg.max_batch {
            return self.flush();
        }
        None
    }

    /// Flush if the oldest item's deadline has expired.
    pub fn poll(&mut self, now_us: u64) -> Option<Vec<T>> {
        match self.oldest_us {
            Some(t0) if now_us.saturating_sub(t0) >= self.cfg.max_delay_us => self.flush(),
            _ => None,
        }
    }

    /// Tick at which the current batch must flush (for dispatcher sleeps).
    pub fn deadline_us(&self) -> Option<u64> {
        self.oldest_us.map(|t0| t0 + self.cfg.max_delay_us)
    }

    /// Tick the current (pending) batch opened at — the arrival of its
    /// oldest item. The dispatcher reads this before flushing so the
    /// flush-assembly span/histogram covers first-enqueue → dispatch.
    pub fn opened_us(&self) -> Option<u64> {
        self.oldest_us
    }

    /// Unconditionally take the pending batch.
    pub fn flush(&mut self) -> Option<Vec<T>> {
        if self.pending.is_empty() {
            return None;
        }
        self.oldest_us = None;
        Some(std::mem::take(&mut self.pending))
    }
}

/// Sliding-window arrival estimator driving the adaptive native flush
/// size: the flush threshold tracks how many requests actually arrive
/// within one batching deadline, so an idle service flushes immediately
/// (batch of 1, minimal latency) while a saturated one fills the
/// configured cap (maximal amortization). Time is passed in explicitly,
/// like [`Batcher`], so the policy is deterministic and testable.
#[derive(Debug)]
pub struct ArrivalRate {
    window_us: u64,
    /// Tick the current window opened at.
    start_us: u64,
    /// Arrivals observed in the current (partial) window.
    count: u64,
    /// Arrivals observed in the last *full* window.
    prev: u64,
}

impl ArrivalRate {
    /// New estimator over windows of `window_us` microseconds.
    pub fn new(window_us: u64) -> Self {
        Self { window_us: window_us.max(1), start_us: 0, count: 0, prev: 0 }
    }

    /// Record one arrival at tick `now_us`.
    pub fn observe(&mut self, now_us: u64) {
        let elapsed = now_us.saturating_sub(self.start_us);
        if elapsed >= self.window_us {
            // Exactly one window rolled over → its count becomes the
            // estimate; a longer gap means the stream went idle.
            self.prev = if elapsed < 2 * self.window_us { self.count } else { 0 };
            self.start_us = now_us - elapsed % self.window_us;
            self.count = 0;
        }
        self.count += 1;
    }

    /// Suggested flush size: the busier of the last full window and the
    /// current partial one, clamped to `[1, cap]` (the configured
    /// `native_max_batch` stays a hard cap).
    pub fn suggest(&self, cap: usize) -> usize {
        let observed = usize::try_from(self.prev.max(self.count)).unwrap_or(usize::MAX);
        observed.clamp(1, cap.max(1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg(max_batch: usize, max_delay_us: u64) -> BatcherConfig {
        BatcherConfig { max_batch, max_delay_us }
    }

    #[test]
    fn flushes_on_size() {
        let mut b = Batcher::new(cfg(3, 1_000));
        assert!(b.push(1, 0).is_none());
        assert!(b.push(2, 10).is_none());
        let batch = b.push(3, 20).expect("full batch");
        assert_eq!(batch, vec![1, 2, 3]);
        assert!(b.is_empty());
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(cfg(10, 500));
        b.push("a", 100);
        assert!(b.poll(400).is_none(), "deadline not reached");
        let batch = b.poll(600).expect("deadline flush");
        assert_eq!(batch, vec!["a"]);
        assert!(b.poll(10_000).is_none(), "nothing left");
    }

    #[test]
    fn deadline_tracks_oldest_item() {
        let mut b = Batcher::new(cfg(10, 500));
        b.push(1, 100);
        b.push(2, 450);
        assert_eq!(b.deadline_us(), Some(600));
        let batch = b.poll(601).unwrap();
        assert_eq!(batch.len(), 2);
        // After a flush the next push restarts the clock.
        b.push(3, 700);
        assert_eq!(b.deadline_us(), Some(1_200));
    }

    #[test]
    fn manual_flush_drains() {
        let mut b = Batcher::new(cfg(10, 500));
        assert!(b.flush().is_none());
        b.push(1, 0);
        b.push(2, 1);
        assert_eq!(b.flush().unwrap(), vec![1, 2]);
        assert!(b.is_empty());
    }

    #[test]
    fn opened_us_tracks_oldest_and_clears_on_flush() {
        let mut b = Batcher::new(cfg(10, 500));
        assert_eq!(b.opened_us(), None);
        b.push(1, 100);
        b.push(2, 300);
        assert_eq!(b.opened_us(), Some(100));
        b.flush();
        assert_eq!(b.opened_us(), None);
    }

    #[test]
    fn batch_size_one_flushes_immediately() {
        let mut b = Batcher::new(cfg(1, 1_000_000));
        assert_eq!(b.push(42, 0).unwrap(), vec![42]);
    }

    #[test]
    fn set_max_batch_applies_on_next_push() {
        let mut b = Batcher::new(cfg(10, 1_000));
        b.push(1, 0);
        b.push(2, 1);
        assert_eq!(b.max_batch(), 10);
        b.set_max_batch(3);
        let batch = b.push(3, 2).expect("shrunk threshold reached");
        assert_eq!(batch, vec![1, 2, 3]);
        b.set_max_batch(0); // clamps to 1
        assert_eq!(b.push(4, 3).unwrap(), vec![4]);
    }

    #[test]
    fn arrival_rate_ramps_under_load() {
        let mut a = ArrivalRate::new(100);
        // Idle start: first arrival suggests a batch of 1.
        a.observe(0);
        assert_eq!(a.suggest(16), 1);
        // 9 more arrivals inside the first window.
        for t in 1..10 {
            a.observe(t * 10);
        }
        assert_eq!(a.suggest(16), 10);
        // Next window: the full previous window keeps the estimate high
        // even while the new window is still sparse.
        a.observe(105);
        assert_eq!(a.suggest(16), 10);
        // The cap binds.
        assert_eq!(a.suggest(4), 4);
    }

    #[test]
    fn arrival_rate_decays_after_idle_gap() {
        let mut a = ArrivalRate::new(100);
        for t in 0..20 {
            a.observe(t * 5);
        }
        a.observe(110);
        assert!(a.suggest(64) > 1, "busy stream suggests batching");
        // A gap of many windows resets the estimate to the new arrival.
        a.observe(10_000);
        assert_eq!(a.suggest(64), 1);
    }

    #[test]
    fn arrival_rate_suggestion_is_at_least_one() {
        let a = ArrivalRate::new(50);
        assert_eq!(a.suggest(8), 1);
        assert_eq!(a.suggest(0), 1);
    }
}
