//! Request routing: payload signature → execution target.
//!
//! A request is routed to a compiled PJRT artifact when its signature
//! (format, mode sizes, input rank) matches the artifact's compiled
//! shapes exactly; anything else falls back to the native engine, which
//! handles arbitrary shapes. Routing is pure and total: every request
//! gets exactly one target.

use crate::runtime::{ArtifactKind, ArtifactSpec};
use crate::tensor::{AnyTensor, Format};
use std::collections::HashMap;

/// The shape signature a request is routed on.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct RouteKey {
    /// Payload format.
    pub format: Format,
    /// Mode sizes.
    pub dims: Vec<usize>,
    /// Input rank (TT: uniform internal rank; CP: rank; dense: none).
    pub input_rank: Option<usize>,
}

impl RouteKey {
    /// Extract the signature of a payload.
    pub fn of(payload: &AnyTensor) -> RouteKey {
        match payload {
            AnyTensor::Dense(t) => RouteKey {
                format: Format::Dense,
                dims: t.dims().to_vec(),
                input_rank: None,
            },
            AnyTensor::Tt(t) => {
                // Uniform internal rank or None (non-uniform TT tensors
                // only run on the native path).
                let inner = &t.ranks()[1..t.ranks().len() - 1];
                let uniform = if inner.is_empty() {
                    Some(1)
                } else if inner.iter().all(|&r| r == inner[0]) {
                    Some(inner[0])
                } else {
                    None
                };
                RouteKey {
                    format: Format::Tt,
                    dims: t.dims().to_vec(),
                    input_rank: uniform,
                }
            }
            AnyTensor::Cp(t) => RouteKey {
                format: Format::Cp,
                dims: t.dims().to_vec(),
                input_rank: Some(t.rank()),
            },
        }
    }
}

/// Where a request executes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum RouteTarget {
    /// Native Rust engine (any shape).
    Native,
    /// Named compiled artifact.
    Pjrt(String),
}

/// The routing table.
#[derive(Debug, Default)]
pub struct Router {
    table: HashMap<RouteKey, String>,
}

impl Router {
    /// Empty router: everything goes native.
    pub fn new() -> Self {
        Self::default()
    }

    /// Register the artifacts a loaded engine exposes. Later registrations
    /// win (so a pallas-path artifact can shadow its reference twin if
    /// registered second).
    pub fn register_artifacts<'a>(&mut self, specs: impl IntoIterator<Item = &'a ArtifactSpec>) {
        for spec in specs {
            let key = match spec.kind {
                ArtifactKind::Tt => RouteKey {
                    format: Format::Tt,
                    dims: spec.input_dims().expect("tt artifact dims"),
                    input_rank: spec.input_rank,
                },
                ArtifactKind::Cp => RouteKey {
                    format: Format::Cp,
                    dims: spec.input_dims().expect("cp artifact dims"),
                    input_rank: spec.input_rank,
                },
                ArtifactKind::Dense => {
                    // Dense artifacts are keyed on the vectorized length;
                    // the canonical dense signature uses a single mode.
                    RouteKey {
                        format: Format::Dense,
                        dims: vec![spec.input_dim.expect("dense artifact dim")],
                        input_rank: None,
                    }
                }
            };
            self.table.insert(key, spec.name.clone());
        }
    }

    /// Number of registered routes.
    pub fn len(&self) -> usize {
        self.table.len()
    }

    /// True when no artifact routes exist.
    pub fn is_empty(&self) -> bool {
        self.table.is_empty()
    }

    /// Route a payload. Dense payloads are matched on their vectorized
    /// length so callers don't need to pre-flatten.
    pub fn route(&self, payload: &AnyTensor) -> RouteTarget {
        let mut key = RouteKey::of(payload);
        if key.format == Format::Dense {
            key.dims = vec![key.dims.iter().product()];
        }
        match self.table.get(&key) {
            Some(name) => RouteTarget::Pjrt(name.clone()),
            None => RouteTarget::Native,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Rng;
    use crate::tensor::{CpTensor, DenseTensor, TtTensor};

    fn tt_spec() -> ArtifactSpec {
        ArtifactSpec {
            name: "tt_rp_tiny".into(),
            kind: ArtifactKind::Tt,
            file: "tt_rp_tiny.hlo.txt".into(),
            k: 4,
            batch: 2,
            scale: 0.5,
            use_pallas: false,
            params: vec![],
            output_shape: vec![2, 4],
            n_modes: Some(4),
            dim: Some(3),
            rank: Some(2),
            input_rank: Some(2),
            input_dim: None,
        }
    }

    #[test]
    fn routes_matching_tt_payload_to_artifact() {
        let mut router = Router::new();
        router.register_artifacts([&tt_spec()]);
        let mut rng = Rng::seed_from(1);
        let x = TtTensor::random(&[3; 4], 2, &mut rng);
        assert_eq!(
            router.route(&AnyTensor::Tt(x)),
            RouteTarget::Pjrt("tt_rp_tiny".into())
        );
    }

    #[test]
    fn mismatched_rank_falls_back_to_native() {
        let mut router = Router::new();
        router.register_artifacts([&tt_spec()]);
        let mut rng = Rng::seed_from(2);
        let x = TtTensor::random(&[3; 4], 5, &mut rng); // rank 5 != 2
        assert_eq!(router.route(&AnyTensor::Tt(x)), RouteTarget::Native);
        let y = TtTensor::random(&[3; 5], 2, &mut rng); // order 5 != 4
        assert_eq!(router.route(&AnyTensor::Tt(y)), RouteTarget::Native);
    }

    #[test]
    fn cp_payload_does_not_match_tt_artifact() {
        let mut router = Router::new();
        router.register_artifacts([&tt_spec()]);
        let mut rng = Rng::seed_from(3);
        let x = CpTensor::random(&[3; 4], 2, &mut rng);
        assert_eq!(router.route(&AnyTensor::Cp(x)), RouteTarget::Native);
    }

    #[test]
    fn dense_matches_on_vectorized_length() {
        let mut spec = tt_spec();
        spec.name = "gauss_tiny".into();
        spec.kind = ArtifactKind::Dense;
        spec.n_modes = None;
        spec.dim = None;
        spec.rank = None;
        spec.input_rank = None;
        spec.input_dim = Some(36);
        let mut router = Router::new();
        router.register_artifacts([&spec]);
        let mut rng = Rng::seed_from(4);
        // 6×6 = 36 → matches even though the payload is 2-mode.
        let x = DenseTensor::random(&[6, 6], &mut rng);
        assert_eq!(
            router.route(&AnyTensor::Dense(x)),
            RouteTarget::Pjrt("gauss_tiny".into())
        );
        let y = DenseTensor::random(&[5, 5], &mut rng);
        assert_eq!(router.route(&AnyTensor::Dense(y)), RouteTarget::Native);
    }

    #[test]
    fn empty_router_is_all_native() {
        let router = Router::new();
        assert!(router.is_empty());
        let mut rng = Rng::seed_from(5);
        let x = TtTensor::random(&[3; 4], 2, &mut rng);
        assert_eq!(router.route(&AnyTensor::Tt(x)), RouteTarget::Native);
    }

    #[test]
    fn non_uniform_tt_rank_goes_native() {
        let mut router = Router::new();
        router.register_artifacts([&tt_spec()]);
        // Build a TT tensor with non-uniform ranks [1, 2, 3, 2, 1].
        let dims = [3usize; 4];
        let ranks = [1usize, 2, 3, 2, 1];
        let cores: Vec<Vec<f64>> = (0..4)
            .map(|n| vec![0.5; ranks[n] * dims[n] * ranks[n + 1]])
            .collect();
        let x = TtTensor::from_cores(&dims, &ranks, cores);
        assert_eq!(router.route(&AnyTensor::Tt(x)), RouteTarget::Native);
    }
}
