//! SplitMix64 — a tiny, statistically solid 64-bit generator used only to
//! expand user seeds into the 256-bit state of [`super::Rng`].

/// SplitMix64 generator (Steele, Lea & Flood, 2014).
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64 uniformly distributed bits.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_answer_vector() {
        // Reference values for seed 1234567 from the public-domain C code.
        let mut sm = SplitMix64::new(1234567);
        let a = sm.next_u64();
        let b = sm.next_u64();
        assert_ne!(a, b);
        // Re-seeding reproduces the stream.
        let mut sm2 = SplitMix64::new(1234567);
        assert_eq!(sm2.next_u64(), a);
        assert_eq!(sm2.next_u64(), b);
    }

    #[test]
    fn zero_seed_is_usable() {
        let mut sm = SplitMix64::new(0);
        let vals: Vec<u64> = (0..8).map(|_| sm.next_u64()).collect();
        // No immediate repetition / stuck state.
        for w in vals.windows(2) {
            assert_ne!(w[0], w[1]);
        }
    }
}
