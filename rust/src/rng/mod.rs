//! Pseudo-random number generation substrate.
//!
//! The paper's projections are built from i.i.d. Gaussian draws (Definitions
//! 1 and 2) and the sparse baselines from Rademacher-style discrete draws
//! (Achlioptas 2003; Li et al. 2006). No external `rand` crate is available
//! offline, so this module implements the full stack from scratch:
//!
//! * [`SplitMix64`] — seed expansion (Steele et al. 2014),
//! * [`Rng`] — xoshiro256++ (Blackman & Vigna 2019), the main generator,
//! * Gaussian sampling via the Marsaglia polar method,
//! * discrete samplers for the sparse / very-sparse RP distributions.
//!
//! Every generator is deterministic from its seed; all experiment configs
//! carry explicit seeds so every figure is exactly re-runnable.

mod gaussian;
mod sparse;
mod splitmix;
mod xoshiro;

pub use gaussian::GaussianSource;
pub use sparse::{SparseEntry, SparseSampler};
pub use splitmix::SplitMix64;
pub use xoshiro::Rng;

/// Derive a child seed from a parent seed and a stream index.
///
/// Used to give independent, reproducible streams to the `k` rows of a
/// projection map or to parallel workers without sharing generator state.
pub fn derive_seed(parent: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(parent ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
    // Burn a few outputs so adjacent streams decorrelate even for tiny seeds.
    sm.next_u64();
    sm.next_u64();
    sm.next_u64()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derive_seed_is_deterministic() {
        assert_eq!(derive_seed(42, 7), derive_seed(42, 7));
    }

    #[test]
    fn derive_seed_differs_across_streams() {
        let s: Vec<u64> = (0..64).map(|i| derive_seed(1, i)).collect();
        for i in 0..s.len() {
            for j in (i + 1)..s.len() {
                assert_ne!(s[i], s[j], "streams {i} and {j} collided");
            }
        }
    }

    #[test]
    fn derive_seed_differs_across_parents() {
        assert_ne!(derive_seed(1, 0), derive_seed(2, 0));
    }
}
