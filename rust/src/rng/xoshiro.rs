//! xoshiro256++ — the crate's main PRNG (Blackman & Vigna, 2019).
//!
//! Chosen for speed (four 64-bit words of state, a handful of ALU ops per
//! draw) and excellent statistical quality — the generator passes BigCrush.
//! Projection-map construction draws hundreds of millions of Gaussians in
//! the experiment sweeps, so draw throughput matters.

use super::splitmix::SplitMix64;

/// xoshiro256++ generator with convenience float / Gaussian methods.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second output of the polar Gaussian transform.
    gauss_spare: Option<f64>,
}

impl Rng {
    /// Seed from a single `u64` via SplitMix64 expansion (the construction
    /// recommended by the xoshiro authors).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s = [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()];
        Self { s, gauss_spare: None }
    }

    /// Fork an independent child generator for stream `i` (see
    /// [`super::derive_seed`]).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(super::derive_seed(self.next_u64(), stream))
    }

    /// Next 64 uniformly distributed bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in `[0, n)` using Lemire's rejection method
    /// (unbiased, one multiply in the common case).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0) is meaningless");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Standard normal draw via the Marsaglia polar method.
    ///
    /// The method produces Gaussians in pairs; the spare is cached so the
    /// amortized cost is ~0.64 uniform pairs per Gaussian.
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        if let Some(g) = self.gauss_spare.take() {
            return g;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    /// Gaussian with the given standard deviation.
    #[inline]
    pub fn gaussian_scaled(&mut self, std: f64) -> f64 {
        self.gaussian() * std
    }

    /// Fill `buf` with i.i.d. `N(0, std²)` draws.
    pub fn fill_gaussian(&mut self, buf: &mut [f64], std: f64) {
        for x in buf.iter_mut() {
            *x = self.gaussian() * std;
        }
    }

    /// Allocate a fresh vector of `n` i.i.d. `N(0, std²)` draws.
    pub fn gaussian_vec(&mut self, n: usize, std: f64) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_gaussian(&mut v, std);
        v
    }

    /// Random Rademacher sign (±1 with equal probability).
    #[inline]
    pub fn sign(&mut self) -> f64 {
        if self.next_u64() & 1 == 0 {
            1.0
        } else {
            -1.0
        }
    }

    /// Bernoulli draw with success probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.uniform() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::seed_from(99);
        let mut b = Rng::seed_from(99);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Rng::seed_from(7);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut rng = Rng::seed_from(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::seed_from(11);
        let mut seen = [false; 10];
        for _ in 0..1_000 {
            let x = rng.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::seed_from(2024);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let kurt = xs.iter().map(|x| x.powi(4)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        // Fourth moment of N(0,1) is 3 — this is exactly the quantity
        // Isserlis' theorem (Lemma 3 of the paper) relies on.
        assert!((kurt - 3.0).abs() < 0.1, "kurtosis={kurt}");
    }

    #[test]
    fn gaussian_scaled_variance() {
        let mut rng = Rng::seed_from(5);
        let n = 100_000;
        let std = 0.25;
        let var = (0..n)
            .map(|_| rng.gaussian_scaled(std).powi(2))
            .sum::<f64>()
            / n as f64;
        assert!((var - std * std).abs() < 0.005, "var={var}");
    }

    #[test]
    fn sign_is_balanced() {
        let mut rng = Rng::seed_from(8);
        let sum: f64 = (0..100_000).map(|_| rng.sign()).sum();
        assert!(sum.abs() < 1_500.0, "sum={sum}");
    }

    #[test]
    fn fork_streams_are_independent_and_deterministic() {
        let mut parent1 = Rng::seed_from(1);
        let mut parent2 = Rng::seed_from(1);
        let mut c1 = parent1.fork(0);
        let mut c2 = parent2.fork(0);
        assert_eq!(c1.next_u64(), c2.next_u64());
        let mut c3 = parent1.fork(1);
        assert_ne!(c1.next_u64(), c3.next_u64());
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::seed_from(17);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
