//! Buffered Gaussian source with per-mode variances.
//!
//! Definitions 1 and 2 of the paper prescribe *different* variances per
//! core/factor position: `1/√R` for TT boundary cores, `1/R` for interior
//! cores, `(1/R)^{1/N}` for every CP factor. [`GaussianSource`] centralizes
//! those rules so the projection constructors cannot get them wrong, and so
//! tests can assert the exact prescription.

use super::Rng;

/// A stream of Gaussian draws tied to one projection map, with helpers for
/// the paper's variance prescriptions.
#[derive(Debug, Clone)]
pub struct GaussianSource {
    rng: Rng,
}

impl GaussianSource {
    /// Create a source from a seed.
    pub fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from(seed) }
    }

    /// Wrap an existing generator.
    pub fn from_rng(rng: Rng) -> Self {
        Self { rng }
    }

    /// Standard deviation of the entries of TT core `n` (0-indexed) out of
    /// `num_modes` cores, for TT rank `r` — Definition 1 of the paper.
    ///
    /// Boundary cores (`n == 0` or `n == N-1`) get variance `1/√R`, interior
    /// cores variance `1/R`; the standard deviation is the square root.
    ///
    /// For `N == 1` the map degenerates to a dense Gaussian RP and the
    /// variance is 1 (the classical JLT), matching the paper's remark that
    /// `R` is necessarily 1 when `N = 1`.
    pub fn tt_core_std(n: usize, num_modes: usize, r: usize) -> f64 {
        assert!(n < num_modes);
        if num_modes == 1 {
            return 1.0;
        }
        let rf = r as f64;
        if n == 0 || n == num_modes - 1 {
            // variance 1/sqrt(R)  =>  std = R^{-1/4}
            rf.powf(-0.25)
        } else {
            // variance 1/R  =>  std = R^{-1/2}
            rf.powf(-0.5)
        }
    }

    /// Standard deviation of CP factor entries for CP rank `r` and tensor
    /// order `num_modes` — Definition 2: variance `(1/R)^{1/N}`.
    pub fn cp_factor_std(num_modes: usize, r: usize) -> f64 {
        let var = (1.0 / r as f64).powf(1.0 / num_modes as f64);
        var.sqrt()
    }

    /// Draw a vector of `n` i.i.d. `N(0, std²)` entries.
    pub fn vector(&mut self, n: usize, std: f64) -> Vec<f64> {
        self.rng.gaussian_vec(n, std)
    }

    /// Access the underlying generator.
    pub fn rng_mut(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tt_boundary_vs_interior_std() {
        let r = 4;
        let b = GaussianSource::tt_core_std(0, 5, r);
        let e = GaussianSource::tt_core_std(4, 5, r);
        let i = GaussianSource::tt_core_std(2, 5, r);
        // variance 1/sqrt(4) = 0.5 -> std = sqrt(0.5)
        assert!((b * b - 0.5).abs() < 1e-12);
        assert!((e * e - 0.5).abs() < 1e-12);
        // variance 1/4 -> std = 0.5
        assert!((i * i - 0.25).abs() < 1e-12);
    }

    #[test]
    fn tt_order_one_degenerates_to_classical() {
        assert_eq!(GaussianSource::tt_core_std(0, 1, 1), 1.0);
    }

    #[test]
    fn cp_factor_variance_product_is_inverse_rank() {
        // The product of the N per-factor variances must be 1/R so that a
        // rank-one component has second moment 1/R and the R-term sum is an
        // expected isometry.
        for &(n, r) in &[(2usize, 3usize), (5, 7), (12, 25)] {
            let std = GaussianSource::cp_factor_std(n, r);
            let prod = (std * std).powi(n as i32);
            assert!((prod - 1.0 / r as f64).abs() < 1e-12, "n={n} r={r}");
        }
    }

    #[test]
    fn vector_has_requested_std() {
        let mut src = GaussianSource::new(31);
        let v = src.vector(100_000, 0.5);
        let var = v.iter().map(|x| x * x).sum::<f64>() / v.len() as f64;
        assert!((var - 0.25).abs() < 0.01, "var={var}");
    }
}
