//! Samplers for the sparse random-projection baselines.
//!
//! * Achlioptas (2003): entries are `±√3` with probability `1/6` each and
//!   `0` with probability `2/3` (the "database-friendly" s = 3 scheme).
//! * Li, Hastie & Church (2006) "very sparse" RP: entries are `±√s` with
//!   probability `1/(2s)` each and `0` otherwise, with `s = √D` where `D`
//!   is the input dimension. This is the baseline used by Figures 1
//!   (medium-order), 2 and 4 of the paper.
//!
//! Both preserve `E[a²] = 1`, which is all the JL analysis needs.

use super::Rng;

/// One nonzero entry of a sparse projection row.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SparseEntry {
    /// Column index within the row.
    pub index: usize,
    /// Entry value (±√s).
    pub value: f64,
}

/// Sampler producing sparse projection rows with the `s`-sparse scheme.
#[derive(Debug, Clone)]
pub struct SparseSampler {
    /// Sparsity parameter: entries are nonzero with probability `1/s`.
    s: f64,
}

impl SparseSampler {
    /// Achlioptas' scheme (`s = 3`).
    pub fn achlioptas() -> Self {
        Self { s: 3.0 }
    }

    /// Li et al.'s very sparse scheme for input dimension `dim`
    /// (`s = √dim`).
    pub fn very_sparse(dim: usize) -> Self {
        Self { s: (dim as f64).sqrt().max(1.0) }
    }

    /// Custom sparsity.
    pub fn with_s(s: f64) -> Self {
        assert!(s >= 1.0, "sparsity parameter must be >= 1");
        Self { s }
    }

    /// The sparsity parameter `s`.
    pub fn s(&self) -> f64 {
        self.s
    }

    /// Expected number of nonzeros in a row of length `dim`.
    pub fn expected_nnz(&self, dim: usize) -> f64 {
        dim as f64 / self.s
    }

    /// Sample one sparse row of length `dim`, returning only the nonzeros.
    ///
    /// Uses geometric skipping: instead of flipping a coin per column, the
    /// gap to the next nonzero is drawn directly from the geometric
    /// distribution, making row generation `O(nnz)` rather than `O(dim)` —
    /// essential when `dim = d^N` is in the hundreds of thousands.
    pub fn sample_row(&self, dim: usize, rng: &mut Rng) -> Vec<SparseEntry> {
        let p = 1.0 / self.s;
        let value_mag = self.s.sqrt();
        let mut entries = Vec::with_capacity((self.expected_nnz(dim) * 1.5) as usize + 4);
        if p >= 0.999_999 {
            // Dense degenerate case (s = 1): every entry is ±1.
            for index in 0..dim {
                entries.push(SparseEntry { index, value: rng.sign() });
            }
            return entries;
        }
        let log1mp = (1.0 - p).ln();
        let mut i: f64 = -1.0;
        loop {
            // Geometric gap: floor(ln(U)/ln(1-p)).
            let u = rng.uniform().max(f64::MIN_POSITIVE);
            i += 1.0 + (u.ln() / log1mp).floor();
            if i >= dim as f64 {
                break;
            }
            entries.push(SparseEntry {
                index: i as usize,
                value: value_mag * rng.sign(),
            });
        }
        entries
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn achlioptas_moments() {
        let sampler = SparseSampler::achlioptas();
        let mut rng = Rng::seed_from(77);
        let dim = 10_000;
        let mut sum = 0.0;
        let mut sumsq = 0.0;
        let trials = 50;
        for _ in 0..trials {
            for e in sampler.sample_row(dim, &mut rng) {
                sum += e.value;
                sumsq += e.value * e.value;
            }
        }
        let n = (dim * trials) as f64;
        // E[a] = 0, E[a²] = 1.
        assert!((sum / n).abs() < 0.02, "mean={}", sum / n);
        assert!((sumsq / n - 1.0).abs() < 0.05, "second moment={}", sumsq / n);
    }

    #[test]
    fn very_sparse_nnz_matches_expectation() {
        let dim = 40_000; // s = 200, expected nnz = 200
        let sampler = SparseSampler::very_sparse(dim);
        let mut rng = Rng::seed_from(5);
        let trials = 100;
        let total: usize = (0..trials)
            .map(|_| sampler.sample_row(dim, &mut rng).len())
            .sum();
        let avg = total as f64 / trials as f64;
        let expect = sampler.expected_nnz(dim);
        assert!(
            (avg - expect).abs() < 0.15 * expect,
            "avg={avg} expect={expect}"
        );
    }

    #[test]
    fn indices_are_strictly_increasing_and_in_range() {
        let sampler = SparseSampler::very_sparse(5_000);
        let mut rng = Rng::seed_from(9);
        let row = sampler.sample_row(5_000, &mut rng);
        for w in row.windows(2) {
            assert!(w[0].index < w[1].index);
        }
        assert!(row.iter().all(|e| e.index < 5_000));
    }

    #[test]
    fn values_are_plus_minus_sqrt_s() {
        let sampler = SparseSampler::with_s(16.0);
        let mut rng = Rng::seed_from(3);
        for e in sampler.sample_row(10_000, &mut rng) {
            assert!((e.value.abs() - 4.0).abs() < 1e-12);
        }
    }

    #[test]
    fn s_equal_one_is_dense_rademacher() {
        let sampler = SparseSampler::with_s(1.0);
        let mut rng = Rng::seed_from(4);
        let row = sampler.sample_row(128, &mut rng);
        assert_eq!(row.len(), 128);
        assert!(row.iter().all(|e| e.value.abs() == 1.0));
    }
}
