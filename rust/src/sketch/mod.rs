//! Sketching extensions — the paper's stated future work (§7): *"fast low
//! rank approximation algorithms for matrices given in the TT format,
//! which could prove particularly useful for designing efficient PCA …"*.
//!
//! This module implements the randomized range finder (Halko, Martinsson &
//! Tropp 2011) with **tensorized test matrices**: the Gaussian test matrix
//! `Ω ∈ R^{cols × s}` is replaced by one whose columns are rank-`R` TT
//! tensors over the column-mode factorization — exactly the `f_TT(R)` rows
//! of Definition 1. The sketch `Y = A·Ω` therefore never materializes `Ω`
//! (`O(s·N·d·R²)` parameters instead of `O(s·d^N)`), and when `A` is a
//! matricization of a TT tensor the product can be computed in compressed
//! form.
//!
//! Pipeline: `Y = A·Ω` → thin QR → `B = QᵀA` → small SVD → truncate.

use crate::linalg::{qr, svd, Matrix, Svd};
use crate::rng::Rng;
use crate::tensor::{DenseTensor, TtDenseContraction, TtTensor};

/// Configuration of a tensorized randomized SVD.
#[derive(Debug, Clone, Copy)]
pub struct SketchConfig {
    /// Target rank of the approximation.
    pub rank: usize,
    /// Oversampling (sketch width = rank + oversample).
    pub oversample: usize,
    /// TT rank of the tensorized test vectors.
    pub tt_rank: usize,
    /// Seed for the test matrix.
    pub seed: u64,
}

impl Default for SketchConfig {
    fn default() -> Self {
        Self { rank: 8, oversample: 8, tt_rank: 2, seed: 0x5E7C }
    }
}

/// Result of a sketched SVD.
pub struct SketchedSvd {
    /// The rank-`r` factorization.
    pub svd: Svd,
    /// Parameters stored by the test matrix (tensorized vs dense).
    pub omega_params: usize,
}

/// Randomized low-rank approximation of `a` (`rows × cols`) where `cols`
/// factorizes as `col_dims` (so test vectors can be TT-structured over
/// the column modes).
pub fn sketched_svd(a: &Matrix, col_dims: &[usize], cfg: SketchConfig) -> SketchedSvd {
    let cols: usize = col_dims.iter().product();
    assert_eq!(a.cols(), cols, "column modes must factorize a.cols()");
    let s = (cfg.rank + cfg.oversample).min(a.rows().min(cols));
    let mut rng = Rng::seed_from(cfg.seed);

    // Tensorized test vectors: s independent Definition-1 TT rows.
    let omegas: Vec<TtTensor> = (0..s)
        .map(|_| TtTensor::random_projection_row(col_dims, cfg.tt_rank, &mut rng))
        .collect();
    let omega_params: usize = omegas.iter().map(|t| t.num_params()).sum();

    // Y = A·Ω as one batched contraction per test vector: the row-major
    // buffer of `a` *is* the stacked batch of its rows viewed as col_dims
    // tensors, so each ω contracts against all rows through a single
    // batch-folded GEMM chain (cores transposed once per ω) instead of
    // rows × s scalar inner products — O(rows·s·cols·R) with GEMM-shaped
    // inner loops and no per-row allocation.
    let mut y = Matrix::zeros(a.rows(), s);
    let mut col = vec![0.0; a.rows()];
    let (mut cur, mut next) = (Vec::new(), Vec::new());
    for (j, om) in omegas.iter().enumerate() {
        let ctx = TtDenseContraction::new(om);
        ctx.inner_stacked_into(a.data(), a.rows(), &mut col, &mut cur, &mut next);
        for (i, &v) in col.iter().enumerate() {
            y[(i, j)] = v;
        }
    }

    // Q = orth(Y); B = QᵀA; SVD(B) and lift back. QᵀA runs through the
    // transpose-gathering GEMM entry (no Qᵀ materialization) — every
    // product in the sketch pipeline now hits the one packed kernel.
    let (q, _) = qr(&y);
    let b = q.t_matmul(a);
    let inner = svd(&b);
    let trunc = inner.truncate(cfg.rank);
    SketchedSvd {
        svd: Svd { u: q.matmul(&trunc.u), s: trunc.s, v: trunc.v },
        omega_params,
    }
}

/// Inner product of a TT tensor with a dense tensor.
///
/// Thin convenience wrapper over the single shared absorption
/// implementation, [`TtDenseContraction`] in `tensor::` (previously this
/// module and `projections::tt` carried duplicated copies of the chain).
/// Repeated contractions against the same TT tensor should construct the
/// context once instead.
pub fn tt_dense_inner(tt: &TtTensor, x: &DenseTensor) -> f64 {
    TtDenseContraction::new(tt).inner(x)
}

/// Sketched PCA: top-`rank` principal directions of row-observations `a`
/// (rows = samples, cols = features factored as `col_dims`), without
/// materializing a dense test matrix.
pub fn sketched_pca(a: &Matrix, col_dims: &[usize], cfg: SketchConfig) -> Svd {
    // Center the columns.
    let mut centered = a.clone();
    for j in 0..a.cols() {
        let mean: f64 = (0..a.rows()).map(|i| a[(i, j)]).sum::<f64>() / a.rows() as f64;
        for i in 0..a.rows() {
            centered[(i, j)] -= mean;
        }
    }
    sketched_svd(&centered, col_dims, cfg).svd
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::rel_err;

    /// Build a rows×cols matrix of known rank.
    fn low_rank_matrix(rows: usize, cols: usize, rank: usize, rng: &mut Rng) -> Matrix {
        let u = Matrix::from_vec(rows, rank, rng.gaussian_vec(rows * rank, 1.0));
        let v = Matrix::from_vec(rank, cols, rng.gaussian_vec(rank * cols, 1.0));
        u.matmul(&v)
    }

    #[test]
    fn recovers_low_rank_matrix() {
        let mut rng = Rng::seed_from(1);
        let col_dims = [4usize, 4, 4]; // cols = 64
        let a = low_rank_matrix(20, 64, 3, &mut rng);
        let out = sketched_svd(
            &a,
            &col_dims,
            SketchConfig { rank: 3, oversample: 10, tt_rank: 3, seed: 5 },
        );
        let rec = out.svd.reconstruct();
        assert!(
            rel_err(rec.data(), a.data()) < 1e-6,
            "rank-3 matrix should be recovered: err={}",
            rel_err(rec.data(), a.data())
        );
    }

    #[test]
    fn near_optimal_on_decaying_spectrum() {
        let mut rng = Rng::seed_from(2);
        let col_dims = [3usize, 3, 3, 3]; // cols = 81
        // Matrix with geometric singular-value decay.
        let rows = 30;
        let u = {
            let (q, _) = qr(&Matrix::from_vec(rows, rows, rng.gaussian_vec(rows * rows, 1.0)));
            q
        };
        let v = {
            let (q, _) = qr(&Matrix::from_vec(81, 81, rng.gaussian_vec(81 * 81, 1.0)));
            q
        };
        let mut a = Matrix::zeros(rows, 81);
        for r in 0..rows.min(81) {
            let sv = 0.6f64.powi(r as i32);
            for i in 0..rows {
                for j in 0..81 {
                    a[(i, j)] += sv * u[(i, r)] * v[(j, r)];
                }
            }
        }
        let rank = 6;
        let out = sketched_svd(
            &a,
            &col_dims,
            SketchConfig { rank, oversample: 12, tt_rank: 3, seed: 9 },
        );
        let err = rel_err(out.svd.reconstruct().data(), a.data());
        // Optimal rank-6 error = σ₇/‖A‖ tail.
        let exact = svd(&a);
        let tail: f64 = exact.s[rank..].iter().map(|x| x * x).sum::<f64>().sqrt();
        let optimal = tail / a.fro_norm();
        assert!(
            err < 6.0 * optimal + 0.05,
            "sketched err {err} vs optimal {optimal}"
        );
    }

    #[test]
    fn tensorized_test_matrix_is_compressed() {
        let mut rng = Rng::seed_from(3);
        let col_dims = [4usize; 6]; // cols = 4096
        let a = low_rank_matrix(10, 4096, 2, &mut rng);
        let out = sketched_svd(
            &a,
            &col_dims,
            SketchConfig { rank: 2, oversample: 6, tt_rank: 2, seed: 4 },
        );
        let dense_params = 4096 * 8; // dense Ω would be cols × s
        assert!(
            out.omega_params < dense_params / 5,
            "tensorized Ω should be ≪ dense: {} vs {}",
            out.omega_params,
            dense_params
        );
    }

    #[test]
    fn tt_dense_inner_matches_densified() {
        let mut rng = Rng::seed_from(4);
        let dims = [3usize, 4, 2, 3];
        let tt = TtTensor::random(&dims, 3, &mut rng);
        let x = DenseTensor::random(&dims, &mut rng);
        let fast = tt_dense_inner(&tt, &x);
        let slow = tt.to_dense().inner(&x);
        assert!((fast - slow).abs() < 1e-9 * slow.abs().max(1.0));
    }

    #[test]
    fn sketched_pca_centers_data() {
        let mut rng = Rng::seed_from(5);
        let col_dims = [3usize, 3];
        // Data with a dominant direction plus an offset.
        let mut a = Matrix::zeros(40, 9);
        let dir = rng.gaussian_vec(9, 1.0);
        for i in 0..40 {
            let t = rng.gaussian();
            for j in 0..9 {
                a[(i, j)] = 5.0 + t * dir[j] + 0.01 * rng.gaussian();
            }
        }
        let p = sketched_pca(&a, &col_dims, SketchConfig { rank: 1, ..Default::default() });
        // Top right-singular vector ≈ ±dir/‖dir‖.
        let norm: f64 = dir.iter().map(|x| x * x).sum::<f64>().sqrt();
        let cos: f64 = (0..9).map(|j| p.v[(j, 0)] * dir[j] / norm).sum::<f64>().abs();
        assert!(cos > 0.98, "principal direction cos={cos}");
    }
}
