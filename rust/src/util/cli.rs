//! Hand-rolled CLI argument parser (no `clap` offline).
//!
//! Supports the subcommand + `--key value` + `--flag` grammar used by the
//! `trp` binary and the benches:
//!
//! ```text
//! trp experiment fig1 --case medium --trials 100 --seed 7 --out results/
//! ```

use std::collections::BTreeMap;

/// Parsed command line: positional arguments and `--key [value]` options.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// Positional arguments in order (e.g. `["experiment", "fig1"]`).
    pub positional: Vec<String>,
    /// Options; flags (no value) map to `"true"`.
    pub options: BTreeMap<String, String>,
}

impl Args {
    /// Parse from an iterator of argument strings (not including argv\[0\]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("empty option name '--'".into());
                }
                // --key=value form.
                if let Some((k, v)) = key.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                    continue;
                }
                // --key value form unless next token is another option.
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        out.options.insert(key.to_string(), v);
                    }
                    _ => {
                        out.options.insert(key.to_string(), "true".to_string());
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    /// Positional argument `i`.
    pub fn pos(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }

    /// String option.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    /// Typed option with default; errors on unparsable values.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, key: &str, default: T) -> Result<T, String> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse::<T>()
                .map_err(|_| format!("invalid value '{v}' for --{key}")),
        }
    }

    /// Boolean flag (present or `--key true`).
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("experiment fig1 --case medium --trials 100 --verbose");
        assert_eq!(a.pos(0), Some("experiment"));
        assert_eq!(a.pos(1), Some("fig1"));
        assert_eq!(a.get("case"), Some("medium"));
        assert_eq!(a.get_parsed_or("trials", 0usize).unwrap(), 100);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn equals_form() {
        let a = parse("--k=64 --name=tt_rp");
        assert_eq!(a.get("k"), Some("64"));
        assert_eq!(a.get("name"), Some("tt_rp"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--dry-run --seed 9");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("seed"), Some("9"));
    }

    #[test]
    fn invalid_parse_reports_key() {
        let a = parse("--trials abc");
        let err = a.get_parsed_or("trials", 1usize).unwrap_err();
        assert!(err.contains("trials"));
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.get_or("case", "small"), "small");
        assert_eq!(a.get_parsed_or("seed", 42u64).unwrap(), 42);
    }
}
