//! Minimal JSON parser and writer.
//!
//! Used for `artifacts/manifest.json` (written by the Python AOT pipeline,
//! read by the Rust runtime), experiment configs and result files. A full
//! recursive-descent parser covering the JSON grammar — objects, arrays,
//! strings with escapes, numbers, booleans, null — with precise error
//! positions. No serde available offline.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`
    Null,
    /// `true` / `false`
    Bool(bool),
    /// Any JSON number (stored as f64, like JavaScript).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys for deterministic output).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    /// Byte offset in the input where the error occurred.
    pub pos: usize,
    /// Human-readable message.
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    /// Parse a JSON document.
    pub fn parse(input: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: input.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// As f64 if a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// As usize if a non-negative integral number.
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    /// As string slice.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// As bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// As array slice.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// As object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Convenience: array of usizes (shapes in the manifest).
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Serialize compactly.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, item)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    item.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

/// Build a `Json::Obj` from key/value pairs.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

/// Build a `Json::Arr` of numbers.
pub fn num_arr(xs: &[f64]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
}

/// Build a `Json::Arr` of usizes.
pub fn usize_arr(xs: &[usize]) -> Json {
    Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_usize(), Some(2));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"shapes": [[2, 3], [4]], "name": "tt_rp", "ok": true, "eps": 0.5}"#;
        let j = Json::parse(src).unwrap();
        let compact = j.to_string_compact();
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), j);
        assert_eq!(Json::parse(&pretty).unwrap(), j);
    }

    #[test]
    fn escapes_roundtrip() {
        let j = Json::Str("quote\" back\\ nl\n tab\t".into());
        let s = j.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn unicode_escape() {
        let j = Json::parse(r#""é""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é");
    }

    #[test]
    fn errors_have_positions() {
        let e = Json::parse("[1, ").unwrap_err();
        assert!(e.pos >= 3);
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("[1] x").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn usize_vec_helper() {
        let j = Json::parse("[3, 12, 5]").unwrap();
        assert_eq!(j.as_usize_vec().unwrap(), vec![3, 12, 5]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_none());
    }

    #[test]
    fn integers_print_without_decimal_point() {
        assert_eq!(Json::Num(128.0).to_string_compact(), "128");
        assert_eq!(Json::Num(0.5).to_string_compact(), "0.5");
    }
}
