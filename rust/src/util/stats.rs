//! Summary statistics used by the experiment harness and bench reports.

/// Summary of a sample: mean, std, min/median/percentiles/max.
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    /// Number of observations.
    pub n: usize,
    /// Sample mean.
    pub mean: f64,
    /// Sample standard deviation (ddof = 1; 0 when n < 2).
    pub std: f64,
    /// Minimum.
    pub min: f64,
    /// Median (p50).
    pub median: f64,
    /// 95th percentile.
    pub p95: f64,
    /// Maximum.
    pub max: f64,
}

impl Summary {
    /// Compute a summary of `xs`. Panics on empty input.
    pub fn of(xs: &[f64]) -> Summary {
        assert!(!xs.is_empty(), "summary of empty sample");
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = xs.to_vec();
        // total_cmp: NaN-safe total order, bit-identical to the old
        // partial_cmp sort on NaN-free data (lint: float-total-order).
        sorted.sort_by(f64::total_cmp);
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            median: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            max: sorted[n - 1],
        }
    }

    /// Standard error of the mean.
    pub fn sem(&self) -> f64 {
        if self.n > 0 {
            self.std / (self.n as f64).sqrt()
        } else {
            0.0
        }
    }
}

/// Linear-interpolation percentile of a pre-sorted slice, `p` in `[0,100]`.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Sample mean.
pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample variance (ddof = 0, i.e. the plain empirical second central
/// moment — matches the quantity bounded by the paper's Theorem 1).
pub fn variance(xs: &[f64]) -> f64 {
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_of_known_sample() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.median - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // std with ddof=1: sqrt(2.5)
        assert!((s.std - 2.5f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 50.0) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 100.0), 10.0);
    }

    #[test]
    fn single_observation() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.std, 0.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.p95, 7.0);
    }

    #[test]
    fn variance_ddof0() {
        assert!((variance(&[1.0, 3.0]) - 1.0).abs() < 1e-12);
    }
}
