//! Poison-tolerant synchronization helpers.
//!
//! A thread that panics while holding a `Mutex` poisons it; the default
//! `.lock().unwrap()` then panics in *every other* thread that touches
//! that lock, cascading one bug into a dead worker pool and a wedged
//! coordinator. Serving state in this crate is kept consistent by the
//! sequencer-turn protocol and per-batch ownership, not by lock
//! poisoning, so the right degradation is to recover the guard and keep
//! serving: the helpers here do that, warn once per process, and count
//! recoveries so tests (and operators, via stderr) can observe that a
//! worker panicked without the process dying.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, Once};

/// Lifetime count of poisoned-lock recoveries (0 in a healthy process).
static POISON_RECOVERIES: AtomicU64 = AtomicU64::new(0);
static WARN_ONCE: Once = Once::new();

fn note_recovery() {
    POISON_RECOVERIES.fetch_add(1, Ordering::Relaxed);
    WARN_ONCE.call_once(|| {
        eprintln!(
            "trp: recovered a poisoned lock (a worker thread panicked); \
             serving continues in degraded mode"
        );
    });
}

/// How many times a poisoned lock has been recovered in this process.
pub fn poison_recoveries() -> u64 {
    POISON_RECOVERIES.load(Ordering::Relaxed)
}

/// Lock `m`, recovering the guard when a panicking thread poisoned it.
pub fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

/// Wait on `cv`, recovering the reacquired guard when poisoned.
pub fn wait_recover<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    match cv.wait(guard) {
        Ok(g) => g,
        Err(poisoned) => {
            note_recovery();
            poisoned.into_inner()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn lock_recover_survives_poison() {
        let m = Arc::new(Mutex::new(7u32));
        let m2 = Arc::clone(&m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap();
            panic!("poison the lock");
        })
        .join();
        assert!(m.is_poisoned());
        let before = poison_recoveries();
        let mut g = lock_recover(&m);
        *g += 1;
        assert_eq!(*g, 8);
        assert!(poison_recoveries() > before);
    }

    #[test]
    fn wait_recover_times_out_cleanly() {
        // Plain happy-path check: wait_recover returns the guard once
        // notified (poisoned condvar waits are covered by the mutex test
        // above — the recovery path is shared).
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let p2 = Arc::clone(&pair);
        let t = std::thread::spawn(move || {
            let (m, cv) = &*p2;
            let mut done = lock_recover(m);
            while !*done {
                done = wait_recover(cv, done);
            }
        });
        {
            let (m, cv) = &*pair;
            *lock_recover(m) = true;
            cv.notify_all();
        }
        t.join().unwrap();
    }
}
