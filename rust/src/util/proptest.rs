//! Miniature property-based testing framework (no `proptest` offline).
//!
//! Provides seeded random case generation, a configurable case count, and
//! greedy input shrinking for integer-vector inputs. Used by the
//! coordinator invariants suite (`rust/tests/coordinator_props.rs`) and by
//! algebraic-property tests across the tensor modules.
//!
//! ```
//! use tensorized_rp::util::proptest::{Config, Gen, run};
//!
//! run("addition commutes", Config::default(), |g| {
//!     let a = g.usize_in(0, 1000);
//!     let b = g.usize_in(0, 1000);
//!     if a + b != b + a {
//!         return Err(format!("a={a} b={b}"));
//!     }
//!     Ok(())
//! });
//! ```

use crate::rng::Rng;

/// Property-test configuration.
#[derive(Debug, Clone, Copy)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Base seed; case `i` uses a seed derived from (seed, i) so failures
    /// reproduce exactly.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 64, seed: 0x7_e57 }
    }
}

impl Config {
    /// Fewer cases — for expensive properties.
    pub fn slow(cases: usize) -> Self {
        Self { cases, ..Self::default() }
    }
}

/// Per-case random input generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Trace of drawn scalars — reported on failure for reproduction.
    trace: Vec<String>,
}

impl Gen {
    fn new(seed: u64) -> Self {
        Self { rng: Rng::seed_from(seed), trace: Vec::new() }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo <= hi);
        let v = lo + self.rng.below((hi - lo + 1) as u64) as usize;
        self.trace.push(format!("usize={v}"));
        v
    }

    /// Uniform f64 in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let v = self.rng.uniform_in(lo, hi);
        self.trace.push(format!("f64={v:.6}"));
        v
    }

    /// Standard normal.
    pub fn gaussian(&mut self) -> f64 {
        let v = self.rng.gaussian();
        self.trace.push(format!("gauss={v:.6}"));
        v
    }

    /// Boolean with probability `p`.
    pub fn bool_with(&mut self, p: f64) -> bool {
        let v = self.rng.bernoulli(p);
        self.trace.push(format!("bool={v}"));
        v
    }

    /// Vector of usizes, each in `[lo, hi]`, with length in `[min_len, max_len]`.
    pub fn usize_vec(
        &mut self,
        min_len: usize,
        max_len: usize,
        lo: usize,
        hi: usize,
    ) -> Vec<usize> {
        let len = self.usize_in(min_len, max_len);
        (0..len).map(|_| self.usize_in(lo, hi)).collect()
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        assert!(!xs.is_empty());
        &xs[self.usize_in(0, xs.len() - 1)]
    }

    /// Access the raw generator (for building tensors etc.).
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run a property over `cfg.cases` random cases; panics with the failing
/// case's seed, index and draw trace on the first counterexample.
pub fn run<F>(name: &str, cfg: Config, mut prop: F)
where
    F: FnMut(&mut Gen) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let case_seed = crate::rng::derive_seed(cfg.seed, case as u64);
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            panic!(
                "property '{name}' failed at case {case} (seed {case_seed:#x}):\n  {msg}\n  \
                 draws: [{}]\n  reproduce with Config {{ cases: 1, seed: {:#x} }} after \
                 deriving case 0",
                g.trace.join(", "),
                case_seed,
            );
        }
    }
}

/// Greedy shrinking for vector-shaped counterexamples: repeatedly try
/// dropping elements and halving values while the predicate still fails,
/// returning the smallest failing input found.
pub fn shrink_usize_vec<F>(mut input: Vec<usize>, fails: F) -> Vec<usize>
where
    F: Fn(&[usize]) -> bool,
{
    debug_assert!(fails(&input), "shrink called with a passing input");
    loop {
        let mut improved = false;
        // Try removing each element.
        let mut i = 0;
        while i < input.len() {
            let mut cand = input.clone();
            cand.remove(i);
            if !cand.is_empty() && fails(&cand) {
                input = cand;
                improved = true;
            } else {
                i += 1;
            }
        }
        // Try halving each element.
        for i in 0..input.len() {
            while input[i] > 1 {
                let mut cand = input.clone();
                cand[i] /= 2;
                if fails(&cand) {
                    input = cand;
                    improved = true;
                } else {
                    break;
                }
            }
        }
        if !improved {
            return input;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        run("tautology", Config { cases: 32, seed: 1 }, |g| {
            let x = g.usize_in(0, 10);
            if x <= 10 { Ok(()) } else { Err("impossible".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'must fail'")]
    fn failing_property_panics_with_trace() {
        run("must fail", Config { cases: 8, seed: 2 }, |g| {
            let x = g.usize_in(0, 100);
            if x < 1000 {
                Err(format!("x={x}"))
            } else {
                Ok(())
            }
        });
    }

    #[test]
    fn cases_are_deterministic() {
        let mut seen1 = Vec::new();
        run("collect1", Config { cases: 5, seed: 9 }, |g| {
            seen1.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        let mut seen2 = Vec::new();
        run("collect2", Config { cases: 5, seed: 9 }, |g| {
            seen2.push(g.usize_in(0, 1_000_000));
            Ok(())
        });
        assert_eq!(seen1, seen2);
    }

    #[test]
    fn shrink_finds_minimal_vector() {
        // Fails whenever the vector contains an element ≥ 10.
        let shrunk = shrink_usize_vec(vec![3, 50, 7, 100], |v| v.iter().any(|&x| x >= 10));
        assert_eq!(shrunk.len(), 1);
        assert!(shrunk[0] >= 10 && shrunk[0] < 20, "shrunk to {shrunk:?}");
    }
}
