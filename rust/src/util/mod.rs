//! Cross-cutting utilities built in-repo (no external crates offline):
//! JSON, CSV, CLI parsing, summary statistics, a thread pool, a bench
//! harness and a miniature property-testing framework.

pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod proptest;
pub mod stats;
pub mod sync;
pub mod threadpool;

/// Wall-clock stopwatch with nanosecond resolution.
#[derive(Debug, Clone, Copy)]
pub struct Timer {
    start: std::time::Instant,
}

impl Timer {
    /// Start a new timer.
    pub fn start() -> Self {
        Self { start: std::time::Instant::now() }
    }

    /// Elapsed seconds.
    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_secs() * 1e3
    }

    /// Elapsed microseconds.
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_secs() * 1e6
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_is_monotone() {
        let t = Timer::start();
        let a = t.elapsed_secs();
        let b = t.elapsed_secs();
        assert!(b >= a);
        assert!(a >= 0.0);
    }
}
