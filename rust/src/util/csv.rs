//! Tiny CSV writer (and reader for tests) used by the experiment harness
//! to emit figure data into `results/`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// An in-memory CSV table with a fixed header.
#[derive(Debug, Clone)]
pub struct CsvTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl CsvTable {
    /// New table with the given column names.
    pub fn new(header: &[&str]) -> Self {
        Self {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of already-formatted cells.
    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "column count mismatch");
        self.rows.push(cells);
    }

    /// Append a row of f64s (formatted with up to 9 significant digits).
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_row(cells.iter().map(|x| format_num(*x)).collect());
    }

    /// Number of data rows.
    pub fn len(&self) -> usize {
        self.rows.len()
    }

    /// True when no data rows.
    pub fn is_empty(&self) -> bool {
        self.rows.is_empty()
    }

    /// Serialize to CSV text.
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        writeln!(out, "{}", self.header.join(",")).unwrap();
        for row in &self.rows {
            writeln!(out, "{}", row.join(",")).unwrap();
        }
        out
    }

    /// Render as a GitHub-flavoured Markdown table (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        writeln!(out, "| {} |", self.header.join(" | ")).unwrap();
        writeln!(
            out,
            "|{}|",
            self.header.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        )
        .unwrap();
        for row in &self.rows {
            writeln!(out, "| {} |", row.join(" | ")).unwrap();
        }
        out
    }

    /// Write CSV to a file, creating parent directories.
    pub fn write_to(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        f.write_all(self.to_csv().as_bytes())
    }

    /// Parse a CSV string (no quoting support — our own output only).
    pub fn parse(text: &str) -> Option<CsvTable> {
        let mut lines = text.lines();
        let header: Vec<String> = lines.next()?.split(',').map(|s| s.to_string()).collect();
        let mut rows = Vec::new();
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            let cells: Vec<String> = line.split(',').map(|s| s.to_string()).collect();
            if cells.len() != header.len() {
                return None;
            }
            rows.push(cells);
        }
        Some(CsvTable { header, rows })
    }
}

/// Format a float compactly but losslessly enough for plotting.
pub fn format_num(x: f64) -> String {
    if x == x.trunc() && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else {
        format!("{x:.6e}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut t = CsvTable::new(&["k", "distortion"]);
        t.push_nums(&[10.0, 0.25]);
        t.push_nums(&[20.0, 0.125]);
        let parsed = CsvTable::parse(&t.to_csv()).unwrap();
        assert_eq!(parsed.len(), 2);
        assert_eq!(parsed.rows[0][0], "10");
    }

    #[test]
    fn markdown_shape() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push_row(vec!["1".into(), "2".into()]);
        let md = t.to_markdown();
        assert!(md.starts_with("| a | b |"));
        assert!(md.contains("|---|---|"));
    }

    #[test]
    #[should_panic]
    fn wrong_arity_panics() {
        let mut t = CsvTable::new(&["a", "b"]);
        t.push_nums(&[1.0]);
    }

    #[test]
    fn num_format() {
        assert_eq!(format_num(42.0), "42");
        assert!(format_num(0.123456789).contains('e'));
    }
}
