//! Miniature benchmark harness (no criterion offline).
//!
//! Provides warmup + timed iterations + summary statistics, and a
//! [`BenchReport`] collector that renders the per-figure tables the
//! `cargo bench` targets print and write into `results/`.

use super::csv::CsvTable;
use super::stats::Summary;
use super::Timer;

/// Configuration for one measured benchmark.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    /// Warmup iterations (not measured).
    pub warmup: usize,
    /// Measured samples.
    pub samples: usize,
    /// Minimum total measured time; samples are raised to reach it.
    pub min_time_secs: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        Self { warmup: 3, samples: 10, min_time_secs: 0.05 }
    }
}

impl BenchConfig {
    /// A faster profile for long-running end-to-end benches.
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 5, min_time_secs: 0.0 }
    }
}

/// Result of one benchmark: per-sample wall times in seconds.
#[derive(Debug, Clone)]
pub struct BenchResult {
    /// Benchmark label.
    pub name: String,
    /// Raw sample times (seconds).
    pub times: Vec<f64>,
}

impl BenchResult {
    /// Summary statistics of the samples.
    pub fn summary(&self) -> Summary {
        Summary::of(&self.times)
    }

    /// Median seconds per iteration.
    pub fn median_secs(&self) -> f64 {
        self.summary().median
    }
}

/// Run `f` under the given config and collect timings.
///
/// `f` should perform one complete unit of the measured work and return a
/// value; the value is passed through `std::hint::black_box` so the
/// optimizer cannot elide the computation.
pub fn bench<T>(name: &str, cfg: BenchConfig, mut f: impl FnMut() -> T) -> BenchResult {
    for _ in 0..cfg.warmup {
        std::hint::black_box(f());
    }
    // Estimate per-iter time to honor min_time.
    let probe = Timer::start();
    std::hint::black_box(f());
    let per_iter = probe.elapsed_secs().max(1e-9);
    let needed = (cfg.min_time_secs / per_iter).ceil() as usize;
    let samples = cfg.samples.max(1).max(needed.min(10_000));
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Timer::start();
        std::hint::black_box(f());
        times.push(t.elapsed_secs());
    }
    BenchResult { name: name.to_string(), times }
}

/// Collects rows of (label, params…, median time) for a figure and renders
/// them as a console table, CSV and Markdown.
pub struct BenchReport {
    title: String,
    table: CsvTable,
}

impl BenchReport {
    /// Start a report with the given column names (first column is the
    /// series label by convention).
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Self { title: title.to_string(), table: CsvTable::new(columns) }
    }

    /// Append a pre-formatted row.
    pub fn push(&mut self, cells: Vec<String>) {
        self.table.push_row(cells);
    }

    /// Report title.
    pub fn title(&self) -> &str {
        &self.title
    }

    /// Emit to stdout and write CSV into `results/<file>`.
    pub fn finish(&self, file: &str) {
        println!("\n=== {} ===", self.title);
        print!("{}", self.table.to_markdown());
        let path = std::path::Path::new("results").join(file);
        match self.table.write_to(&path) {
            Ok(()) => println!("[written {}]", path.display()),
            Err(e) => eprintln!("[warn] could not write {}: {e}", path.display()),
        }
    }

    /// Access the underlying table (tests).
    pub fn table(&self) -> &CsvTable {
        &self.table
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let cfg = BenchConfig { warmup: 1, samples: 5, min_time_secs: 0.0 };
        let r = bench("noop", cfg, || 1 + 1);
        assert_eq!(r.times.len(), 5);
        assert!(r.median_secs() >= 0.0);
    }

    #[test]
    fn min_time_raises_sample_count() {
        let cfg = BenchConfig { warmup: 0, samples: 1, min_time_secs: 0.02 };
        let r = bench("sleepy", cfg, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(r.times.len() >= 10, "got {} samples", r.times.len());
    }

    #[test]
    fn report_accumulates_rows() {
        let mut rep = BenchReport::new("test", &["series", "k", "secs"]);
        rep.push(vec!["tt".into(), "10".into(), "0.5".into()]);
        assert_eq!(rep.table().len(), 1);
        assert_eq!(rep.title(), "test");
    }
}
