//! Fixed-size thread pool with bounded work queue.
//!
//! Serves two roles: the coordinator's worker pool (bounded queue =
//! backpressure) and a `scope`-style parallel-for for the experiment
//! sweeps. Built on `std::thread` + channels (no tokio/rayon offline).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::util::sync::lock_recover;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Count of jobs that panicked inside a pool worker (the workers survive;
/// this is the observable trace that something did go wrong).
static JOBS_PANICKED: AtomicUsize = AtomicUsize::new(0);

fn warn_job_panicked() {
    JOBS_PANICKED.fetch_add(1, Ordering::SeqCst);
    eprintln!("trp: a pool job panicked; the worker thread recovered");
}

/// Lifetime count of pool jobs that panicked (0 in a healthy process).
pub fn jobs_panicked() -> usize {
    JOBS_PANICKED.load(Ordering::SeqCst)
}

/// A fixed pool of worker threads consuming from a bounded queue.
pub struct ThreadPool {
    tx: Option<SyncSender<Job>>,
    workers: Vec<JoinHandle<()>>,
    queued: Arc<AtomicUsize>,
}

impl ThreadPool {
    /// Create a pool with `threads` workers and a queue bound of `cap`
    /// pending jobs (senders block when full — natural backpressure).
    pub fn new(threads: usize, cap: usize) -> Self {
        assert!(threads > 0);
        let (tx, rx) = sync_channel::<Job>(cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let queued = Arc::new(AtomicUsize::new(0));
        let workers = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                let queued = Arc::clone(&queued);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = lock_recover(&rx);
                        guard.recv()
                    };
                    match job {
                        Ok(job) => {
                            queued.fetch_sub(1, Ordering::SeqCst);
                            // A panicking job must not kill the worker:
                            // the pool is fixed-size, so every lost
                            // thread permanently shrinks serving
                            // capacity. Contain the panic, log once,
                            // keep draining the queue.
                            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                            if r.is_err() {
                                warn_job_panicked();
                            }
                        }
                        Err(_) => break, // channel closed: shut down
                    }
                })
            })
            .collect();
        Self { tx: Some(tx), workers, queued }
    }

    /// Pool sized to available parallelism with a 2× queue.
    pub fn with_default_size() -> Self {
        let n = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4);
        Self::new(n, n * 2)
    }

    /// Submit a job, blocking if the queue is full (backpressure).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        self.queued.fetch_add(1, Ordering::SeqCst);
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("workers gone");
    }

    /// Try to submit without blocking; returns `false` when the queue is
    /// full (the coordinator uses this for load-shedding decisions).
    pub fn try_submit<F: FnOnce() + Send + 'static>(&self, f: F) -> bool {
        self.queued.fetch_add(1, Ordering::SeqCst);
        match self.tx.as_ref().expect("pool shut down").try_send(Box::new(f)) {
            Ok(()) => true,
            Err(TrySendError::Full(_)) | Err(TrySendError::Disconnected(_)) => {
                self.queued.fetch_sub(1, Ordering::SeqCst);
                false
            }
        }
    }

    /// Jobs submitted but not yet started.
    pub fn queue_depth(&self) -> usize {
        self.queued.load(Ordering::SeqCst)
    }

    /// Number of worker threads.
    pub fn num_threads(&self) -> usize {
        self.workers.len()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        drop(self.tx.take()); // close the channel so workers exit
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// Parallel map over `items`, preserving order, using transient scoped
/// threads (chunked). Used by the experiment harness for trial loops.
pub fn par_map<T, R, F>(items: Vec<T>, threads: usize, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || items.len() <= 1 {
        return items.into_iter().map(f).collect();
    }
    let n = items.len();
    let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    // Move items into Option cells so workers can take them by index.
    let cells: Vec<Mutex<Option<T>>> = items.into_iter().map(|x| Mutex::new(Some(x))).collect();
    let out = Mutex::new(&mut slots);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::SeqCst);
                if i >= n {
                    break;
                }
                let item = cells[i].lock().unwrap().take().unwrap();
                let r = f(item);
                out.lock().unwrap()[i] = Some(r);
            });
        }
    });
    slots.into_iter().map(|s| s.unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn pool_runs_all_jobs() {
        let pool = ThreadPool::new(4, 8);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn try_submit_sheds_when_full() {
        let pool = ThreadPool::new(1, 1);
        let gate = Arc::new(Mutex::new(()));
        let held = gate.lock().unwrap();
        // First job blocks the single worker…
        let g2 = Arc::clone(&gate);
        pool.submit(move || {
            let _guard = g2.lock().unwrap();
        });
        // Give the worker a moment to pick up the blocking job.
        std::thread::sleep(std::time::Duration::from_millis(20));
        // …fill the queue…
        pool.submit(|| {});
        // …so this one must shed.
        let accepted = pool.try_submit(|| {});
        assert!(!accepted, "queue should be full");
        drop(held);
    }

    #[test]
    fn panicking_job_does_not_kill_worker() {
        let pool = ThreadPool::new(1, 8);
        let before = jobs_panicked();
        pool.submit(|| panic!("injected worker panic"));
        // The single worker must survive to run the follow-up job.
        let counter = Arc::new(AtomicU64::new(0));
        let c = Arc::clone(&counter);
        pool.submit(move || {
            c.fetch_add(1, Ordering::SeqCst);
        });
        drop(pool); // join
        assert_eq!(counter.load(Ordering::SeqCst), 1);
        assert!(jobs_panicked() > before);
    }

    #[test]
    fn par_map_preserves_order() {
        let xs: Vec<usize> = (0..1000).collect();
        let ys = par_map(xs, 8, |x| x * 2);
        for (i, y) in ys.iter().enumerate() {
            assert_eq!(*y, i * 2);
        }
    }

    #[test]
    fn par_map_single_thread_fallback() {
        let ys = par_map(vec![1, 2, 3], 1, |x| x + 1);
        assert_eq!(ys, vec![2, 3, 4]);
    }

    #[test]
    fn queue_depth_reports() {
        let pool = ThreadPool::new(1, 4);
        assert_eq!(pool.queue_depth(), 0);
        assert_eq!(pool.num_threads(), 1);
    }
}
