//! The committed lint baseline: grandfathered findings that are known,
//! tracked, and excluded from the gate without a per-site waiver.
//!
//! Each entry is `rule<TAB>path<TAB>hash`, where `hash` is the FNV-1a
//! digest of the flagged line's *stripped, trimmed* code — so the entry
//! survives reformatting and line drift but dies (goes stale) the
//! moment the offending code changes, forcing a fresh decision. Every
//! entry is consumed at most once per run; leftovers are reported as
//! stale so the file cannot silently rot.

use std::path::Path;

/// FNV-1a over the bytes of `s` — stable, dependency-free, and plenty
/// for distinguishing source lines.
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[derive(Debug, Clone, PartialEq)]
struct Entry {
    rule: String,
    path: String,
    hash: u64,
    used: bool,
}

/// A parsed baseline file plus per-run consumption state.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    entries: Vec<Entry>,
}

impl Baseline {
    /// Parse baseline text. Blank lines and `#` comments are ignored.
    pub fn parse(text: &str) -> Result<Baseline, String> {
        let mut entries = Vec::new();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split('\t');
            let (rule, path, hash) = match (parts.next(), parts.next(), parts.next()) {
                (Some(r), Some(p), Some(h)) => (r, p, h),
                _ => return Err(format!("baseline line {}: want rule<TAB>path<TAB>hash", i + 1)),
            };
            let hash = u64::from_str_radix(hash, 16)
                .map_err(|_| format!("baseline line {}: bad hash {hash:?}", i + 1))?;
            entries.push(Entry {
                rule: rule.to_string(),
                path: path.to_string(),
                hash,
                used: false,
            });
        }
        Ok(Baseline { entries })
    }

    /// Load from `path`; a missing file is an empty baseline.
    pub fn load(path: &Path) -> Result<Baseline, String> {
        match std::fs::read_to_string(path) {
            Ok(text) => Self::parse(&text),
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Baseline::default()),
            Err(e) => Err(format!("read {}: {e}", path.display())),
        }
    }

    /// Consume one matching entry, if any. Each entry absorbs a single
    /// finding per run, so duplicating a line past its baselined count
    /// still fails the gate.
    pub fn consume(&mut self, rule: &str, path: &str, line_code: &str) -> bool {
        let h = fnv1a(line_code.trim());
        for e in &mut self.entries {
            if !e.used && e.rule == rule && e.path == path && e.hash == h {
                e.used = true;
                return true;
            }
        }
        false
    }

    /// Entries no run finding matched — dead weight to prune.
    pub fn stale(&self) -> usize {
        self.entries.iter().filter(|e| !e.used).count()
    }

    /// Serialize findings as baseline text (sorted, deduplicated).
    pub fn render(findings: &[(String, String, String)]) -> String {
        let mut rows: Vec<String> = findings
            .iter()
            .map(|(rule, path, code)| format!("{rule}\t{path}\t{:016x}", fnv1a(code.trim())))
            .collect();
        rows.sort();
        rows.dedup();
        let mut out = String::from(
            "# trp lint baseline — grandfathered findings (rule<TAB>path<TAB>fnv1a of the\n\
             # stripped line). Regenerate with `trp lint --write-baseline`; keep it empty.\n",
        );
        for r in rows {
            out.push_str(&r);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_consume_and_stale() {
        let code = "let x = y.partial_cmp(z);";
        let text = format!(
            "# comment\n\nfloat-total-order\tsrc/a.rs\t{:016x}\nno-fma\tsrc/b.rs\t{:016x}\n",
            fnv1a(code),
            fnv1a("a.mul_add(b, c)")
        );
        let mut b = Baseline::parse(&text).unwrap();
        assert!(b.consume("float-total-order", "src/a.rs", &format!("  {code}  ")));
        // Same entry does not absorb a second finding.
        assert!(!b.consume("float-total-order", "src/a.rs", code));
        assert!(!b.consume("no-fma", "src/b.rs", "different code"));
        assert_eq!(b.stale(), 1);
    }

    #[test]
    fn render_roundtrips_through_parse() {
        let findings = vec![
            ("no-fma".to_string(), "src/b.rs".to_string(), "a.mul_add(b, c)".to_string()),
            ("no-fma".to_string(), "src/b.rs".to_string(), "a.mul_add(b, c)".to_string()),
        ];
        let text = Baseline::render(&findings);
        let mut b = Baseline::parse(&text).unwrap();
        assert!(b.consume("no-fma", "src/b.rs", "a.mul_add(b, c)"));
        assert_eq!(b.stale(), 0);
    }

    #[test]
    fn bad_lines_are_rejected() {
        assert!(Baseline::parse("just-one-field\n").is_err());
        assert!(Baseline::parse("rule\tpath\tnothex\n").is_err());
    }
}
