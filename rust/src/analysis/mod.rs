//! `trp lint` — the crate's own determinism & concurrency static
//! analysis, run over its own source tree and enforced as a tier-1
//! gate.
//!
//! The serving contract this repo makes (bit-identical replies for an
//! identical request stream, regardless of shard count, worker
//! interleaving, or tracing) rests on a handful of source-level
//! invariants that the compiler does not check: floats are ordered with
//! a total order, the numeric core never fuses multiply-adds, the
//! serving path never panics, hash-map iteration order never reaches an
//! output, `unsafe` stays inside three audited modules with written
//! justifications, `Ordering::Relaxed` never carries a cross-thread
//! handoff, and the durability files never publish or acknowledge bytes
//! that were not fsynced. This module checks all seven textually:
//!
//! * [`lexer`] strips comments and literal bodies so rules match only
//!   real code;
//! * [`rules`] holds the seven-rule catalog with its scoping tables;
//! * [`baseline`] grandfathers known findings by content hash;
//! * this file runs the engine: source walk, waiver resolution, report
//!   assembly, text/JSON rendering.
//!
//! Intentional exceptions are waived **at the site** with a
//! `lint:allow` comment naming the rule and a mandatory reason — e.g.
//! `// lint:allow(unordered-iteration): feeds an order-insensitive
//! reduction.` — on the offending line or the comment line(s) directly
//! above it; `lint:allow-file` scopes the waiver to a module audited as
//! a unit. `trp lint` exits nonzero on any unwaived, unbaselined
//! finding, which is exactly what the `lint_clean` tier-1 gate asserts.

pub mod baseline;
pub mod lexer;
pub mod rules;

use crate::util::json::{obj, Json};
use baseline::Baseline;
use lexer::StrippedLine;
use std::path::{Path, PathBuf};

pub use rules::RULE_IDS;

/// One finding: a rule tripped at a source line.
#[derive(Debug, Clone, PartialEq)]
pub struct Diagnostic {
    /// Rule id (one of [`rules::RULE_IDS`], or `waiver-syntax`).
    pub rule: &'static str,
    /// Crate-relative path with forward slashes.
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Human-readable explanation.
    pub message: String,
}

impl Diagnostic {
    /// `path:line rule-id message` — the stable text form promised by
    /// the `trp lint` CLI contract.
    pub fn render(&self) -> String {
        format!("{}:{} {} {}", self.path, self.line, self.rule, self.message)
    }

    fn to_json(&self) -> Json {
        obj(vec![
            ("rule", Json::Str(self.rule.to_string())),
            ("path", Json::Str(self.path.clone())),
            ("line", Json::Num(self.line as f64)),
            ("message", Json::Str(self.message.clone())),
        ])
    }
}

/// A parsed `lint:allow` comment.
#[derive(Debug, Clone)]
struct Waiver {
    rules: Vec<String>,
    reason: String,
    file_wide: bool,
    /// Line the waiver comment sits on (1-based).
    at: usize,
    /// Code line the waiver covers (resolved; file-wide waivers cover all).
    target: usize,
}

/// Scan one line's comment text for waivers. Malformed waivers become
/// `waiver-syntax` diagnostics — they can NOT be waived or baselined.
fn parse_waivers(
    comment: &str,
    path: &str,
    lineno: usize,
    out: &mut Vec<Waiver>,
    errs: &mut Vec<Diagnostic>,
) {
    let mut rest = comment;
    let mut base = 0usize;
    while let Some(pos) = rest.find("lint:allow") {
        let after = &rest[pos + "lint:allow".len()..];
        let (file_wide, after) = match after.strip_prefix("-file") {
            Some(a) => (true, a),
            None => (false, after),
        };
        let bad = |errs: &mut Vec<Diagnostic>, msg: &str| {
            errs.push(Diagnostic {
                rule: "waiver-syntax",
                path: path.to_string(),
                line: lineno,
                message: msg.to_string(),
            });
        };
        let Some(after) = after.strip_prefix('(') else {
            // A prose mention of the grammar (no rule list follows), not
            // a waiver attempt. Skipping is fail-safe: the finding it
            // failed to waive stays visible.
            base += pos + 1;
            rest = &comment[base..];
            continue;
        };
        let Some(close) = after.find(')') else {
            bad(errs, "malformed waiver: unclosed rule list");
            return;
        };
        let rule_list = &after[..close];
        let tail = &after[close + 1..];
        let Some(tail) = tail.trim_start().strip_prefix(':') else {
            bad(errs, "malformed waiver: missing `: <reason>` after the rule list");
            return;
        };
        // The reason runs to the end of this comment line.
        let reason_end = tail.find('\n').unwrap_or(tail.len());
        let reason = tail[..reason_end].trim().to_string();
        let mut rules_named = Vec::new();
        for r in rule_list.split(',') {
            let r = r.trim();
            if rules::RULE_IDS.contains(&r) {
                rules_named.push(r.to_string());
            } else {
                bad(errs, &format!("waiver names unknown rule {r:?}"));
            }
        }
        if reason.is_empty() {
            bad(errs, "waiver without a reason: every exception must say why");
        } else if !rules_named.is_empty() {
            out.push(Waiver {
                rules: rules_named,
                reason,
                file_wide,
                at: lineno,
                target: lineno, // resolved by `resolve_waiver_targets`
            });
        }
        base += pos + 1;
        rest = &comment[base..];
    }
}

/// A waiver on a code-bearing line covers that line; a waiver on a
/// comment-only line covers the next code-bearing line (so a waiver
/// comment may span several lines above its target).
fn resolve_waiver_targets(waivers: &mut [Waiver], lines: &[StrippedLine]) {
    for w in waivers.iter_mut() {
        if w.file_wide {
            continue;
        }
        let own = &lines[w.at - 1];
        if !own.code.trim().is_empty() {
            w.target = w.at;
            continue;
        }
        w.target = lines
            .iter()
            .enumerate()
            .skip(w.at)
            .take(10)
            .find(|(_, l)| !l.code.trim().is_empty())
            .map(|(i, _)| i + 1)
            .unwrap_or(w.at);
    }
}

/// The outcome of linting a tree.
#[derive(Debug, Clone, Default)]
pub struct LintReport {
    /// Unwaived, unbaselined findings — these fail the gate.
    pub violations: Vec<Diagnostic>,
    /// Findings covered by a site or file waiver, with the written reason.
    pub waived: Vec<(Diagnostic, String)>,
    /// Findings absorbed by the committed baseline.
    pub baselined: Vec<Diagnostic>,
    /// Baseline entries nothing matched (prune them).
    pub stale_baseline: usize,
    /// Files scanned.
    pub files: usize,
}

impl LintReport {
    /// Stable text rendering: one `path:line rule message` per finding
    /// (sorted by path, line, rule), then a one-line summary.
    pub fn to_text(&self) -> String {
        let mut out = String::new();
        for d in &self.violations {
            out.push_str(&d.render());
            out.push('\n');
        }
        out.push_str(&format!(
            "lint: {} violations, {} waived, {} baselined ({} stale), {} files\n",
            self.violations.len(),
            self.waived.len(),
            self.baselined.len(),
            self.stale_baseline,
            self.files
        ));
        out
    }

    /// JSON rendering for CI artifacts.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("violations", Json::Arr(self.violations.iter().map(|d| d.to_json()).collect())),
            (
                "waived",
                Json::Arr(
                    self.waived
                        .iter()
                        .map(|(d, reason)| match d.to_json() {
                            Json::Obj(mut m) => {
                                m.insert("reason".to_string(), Json::Str(reason.clone()));
                                Json::Obj(m)
                            }
                            other => other,
                        })
                        .collect(),
                ),
            ),
            ("baselined", Json::Arr(self.baselined.iter().map(|d| d.to_json()).collect())),
            (
                "summary",
                obj(vec![
                    ("violations", Json::Num(self.violations.len() as f64)),
                    ("waived", Json::Num(self.waived.len() as f64)),
                    ("baselined", Json::Num(self.baselined.len() as f64)),
                    ("stale_baseline", Json::Num(self.stale_baseline as f64)),
                    ("files", Json::Num(self.files as f64)),
                ]),
            ),
        ])
    }
}

fn sort_diags(v: &mut [Diagnostic]) {
    v.sort_by(|a, b| {
        a.path.cmp(&b.path).then(a.line.cmp(&b.line)).then(a.rule.cmp(b.rule))
    });
}

/// Recursively collect `.rs` files under `dir`, as crate-relative
/// forward-slash paths, sorted for a stable report.
fn collect_sources(root: &Path) -> Result<Vec<(String, PathBuf)>, String> {
    let mut out = Vec::new();
    for top in ["src", "tests", "benches", "examples"] {
        let dir = root.join(top);
        if dir.is_dir() {
            walk(&dir, top, &mut out)?;
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

fn walk(dir: &Path, rel: &str, out: &mut Vec<(String, PathBuf)>) -> Result<(), String> {
    let rd = std::fs::read_dir(dir).map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    let mut entries: Vec<_> = rd
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| format!("read_dir {}: {e}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for e in entries {
        let name = e.file_name().to_string_lossy().into_owned();
        let path = e.path();
        let rel_child = format!("{rel}/{name}");
        if path.is_dir() {
            if name != "target" && name != "vendor" {
                walk(&path, &rel_child, out)?;
            }
        } else if name.ends_with(".rs") {
            out.push((rel_child, path));
        }
    }
    Ok(())
}

/// Lint one file's text (exposed for fixture tests).
pub fn lint_source(path: &str, source: &str, baseline: &mut Baseline) -> LintReport {
    let lines = lexer::strip(source);
    let mut waivers = Vec::new();
    let mut waiver_errs = Vec::new();
    for (i, l) in lines.iter().enumerate() {
        if l.comment.contains("lint:allow") {
            parse_waivers(&l.comment, path, i + 1, &mut waivers, &mut waiver_errs);
        }
    }
    resolve_waiver_targets(&mut waivers, &lines);

    let mut report = LintReport { files: 1, ..Default::default() };
    report.violations.extend(waiver_errs);
    for d in rules::run_rules(path, &lines) {
        let waiver = waivers.iter().find(|w| {
            w.rules.iter().any(|r| r == d.rule) && (w.file_wide || w.target == d.line)
        });
        if let Some(w) = waiver {
            report.waived.push((d, w.reason.clone()));
            continue;
        }
        let code = lines.get(d.line - 1).map(|l| l.code.as_str()).unwrap_or("");
        if baseline.consume(d.rule, d.path.as_str(), code) {
            report.baselined.push(d);
        } else {
            report.violations.push(d);
        }
    }
    report
}

/// Lint the crate tree rooted at `root` (the directory holding `src/`).
/// The baseline is consumed across all files; stale entries are counted
/// at the end.
pub fn lint_root(root: &Path, mut baseline: Baseline) -> Result<LintReport, String> {
    let sources = collect_sources(root)?;
    if sources.is_empty() {
        return Err(format!("{}: no Rust sources found (is this the crate root?)", root.display()));
    }
    let mut report = LintReport::default();
    for (rel, path) in &sources {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let file_report = lint_source(rel, &text, &mut baseline);
        report.violations.extend(file_report.violations);
        report.waived.extend(file_report.waived);
        report.baselined.extend(file_report.baselined);
        report.files += 1;
    }
    report.stale_baseline = baseline.stale();
    sort_diags(&mut report.violations);
    sort_diags(&mut report.baselined);
    report.waived.sort_by(|a, b| {
        a.0.path.cmp(&b.0.path).then(a.0.line.cmp(&b.0.line)).then(a.0.rule.cmp(b.0.rule))
    });
    Ok(report)
}

/// All (rule, path, stripped-code) triples a `--write-baseline` run
/// should grandfather: the current unwaived findings.
pub fn baseline_rows(root: &Path) -> Result<Vec<(String, String, String)>, String> {
    let report = lint_root(root, Baseline::default())?;
    let mut rows = Vec::new();
    for d in &report.violations {
        if d.rule == "waiver-syntax" {
            continue; // fix these, don't grandfather them
        }
        let text = std::fs::read_to_string(root.join(&d.path))
            .map_err(|e| format!("read {}: {e}", d.path))?;
        let lines = lexer::strip(&text);
        let code = lines.get(d.line - 1).map(|l| l.code.clone()).unwrap_or_default();
        rows.push((d.rule.to_string(), d.path.clone(), code));
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn site_waiver_covers_same_line_and_line_above() {
        let mut b = Baseline::default();
        let same = "v.sort_by(|a, b| a.partial_cmp(b).unwrap()); // lint:allow(float-total-order): legacy ordering kept for the fixture.\n";
        let r = lint_source("src/util/x.rs", same, &mut b);
        assert!(r.violations.is_empty());
        assert_eq!(r.waived.len(), 1);
        assert_eq!(r.waived[0].1, "legacy ordering kept for the fixture.");

        let above = "// lint:allow(float-total-order): spans two comment lines\n// before the code it waives.\nv.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        let r = lint_source("src/util/x.rs", above, &mut b);
        assert!(r.violations.is_empty());
        assert_eq!(r.waived.len(), 1);
    }

    #[test]
    fn waiver_does_not_leak_to_other_lines_or_rules() {
        let mut b = Baseline::default();
        let src = "// lint:allow(no-fma): wrong rule for the site below.\nv.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        let r = lint_source("src/util/x.rs", src, &mut b);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "float-total-order");
        assert!(r.waived.is_empty());
    }

    #[test]
    fn file_waiver_covers_every_site_of_that_rule() {
        let mut b = Baseline::default();
        let src = "// lint:allow-file(float-total-order): fixture file is all about partial_cmp.\nlet a = x.partial_cmp(&y);\nlet b = x.partial_cmp(&z);\n";
        let r = lint_source("src/util/x.rs", src, &mut b);
        assert!(r.violations.is_empty());
        assert_eq!(r.waived.len(), 2);
    }

    #[test]
    fn reasonless_or_unknown_waivers_are_violations() {
        let mut b = Baseline::default();
        let r = lint_source("src/util/x.rs", "let y = 1; // lint:allow(float-total-order):\n", &mut b);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "waiver-syntax");

        let r = lint_source("src/util/x.rs", "let y = 1; // lint:allow(not-a-rule): reason\n", &mut b);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(r.violations[0].rule, "waiver-syntax");
    }

    #[test]
    fn prose_mention_of_the_grammar_is_not_a_waiver() {
        let mut b = Baseline::default();
        let src = "// a `lint:allow` comment names the rule and gives a reason.\nlet y = 1;\n";
        let r = lint_source("src/util/x.rs", src, &mut b);
        assert!(r.violations.is_empty());
        assert!(r.waived.is_empty());
    }

    #[test]
    fn baseline_absorbs_then_goes_stale() {
        let src = "let a = x.partial_cmp(&y);\n";
        let rows = vec![(
            "float-total-order".to_string(),
            "src/util/x.rs".to_string(),
            "let a = x.partial_cmp(&y);".to_string(),
        )];
        let mut b = Baseline::parse(&Baseline::render(&rows)).unwrap();
        let r = lint_source("src/util/x.rs", src, &mut b);
        assert!(r.violations.is_empty());
        assert_eq!(r.baselined.len(), 1);
        assert_eq!(b.stale(), 0);

        // Changed code no longer matches the baselined hash.
        let mut b = Baseline::parse(&Baseline::render(&rows)).unwrap();
        let r = lint_source("src/util/x.rs", "let a = z.partial_cmp(&y);\n", &mut b);
        assert_eq!(r.violations.len(), 1);
        assert_eq!(b.stale(), 1);
    }

    #[test]
    fn report_renders_text_and_json() {
        let mut b = Baseline::default();
        let r = lint_source("src/util/x.rs", "let a = x.partial_cmp(&y);\n", &mut b);
        let text = r.to_text();
        assert!(text.contains("src/util/x.rs:1 float-total-order"));
        assert!(text.contains("lint: 1 violations"));
        let j = r.to_json();
        let v = j.get("violations").and_then(Json::as_arr).unwrap();
        assert_eq!(v.len(), 1);
        assert_eq!(v[0].get("line").and_then(Json::as_usize), Some(1));
        assert_eq!(
            j.get("summary").and_then(|s| s.get("violations")).and_then(Json::as_usize),
            Some(1)
        );
    }

    #[test]
    fn multi_rule_waiver_list() {
        let mut b = Baseline::default();
        let src = "// lint:allow(float-total-order, no-fma): one comment, two rules.\nlet a = x.partial_cmp(&y);\n";
        let r = lint_source("src/util/x.rs", src, &mut b);
        assert!(r.violations.is_empty());
        assert_eq!(r.waived.len(), 1);
    }
}
