//! The `trp lint` rule catalog: determinism and concurrency contracts
//! this crate promises (bit-identical replies for identical request
//! streams, no panics on the serving path, audited `unsafe`), checked
//! textually over the stripped source (see [`super::lexer`]).
//!
//! Every rule is scoped tightly enough to stay quiet on idiomatic code;
//! intentional exceptions carry a `lint:allow` waiver at the site (see
//! [`super`]) so the contract and its escape hatches are both
//! reviewable in the diff.

use super::lexer::StrippedLine;
use super::Diagnostic;

/// Every rule id, for waiver validation and `--help` text.
pub const RULE_IDS: &[&str] = &[
    "float-total-order",
    "no-fma",
    "hot-path-panic",
    "unordered-iteration",
    "unsafe-audit",
    "relaxed-handoff",
    "fsync-discipline",
];

/// Hot serving path: a panic here kills a worker or wedges a lane. The
/// WAL engine is on it — an append or group-commit runs inside every
/// mutation flush. The SLO sampler runs beside it: a panic there would
/// silently stop alarm evaluation while serving continues.
const HOT_PATHS: &[&str] = &[
    "src/coordinator/server.rs",
    "src/coordinator/net.rs",
    "src/coordinator/state.rs",
    "src/coordinator/batcher.rs",
    "src/index/wal.rs",
    "src/obs/slo.rs",
];

/// Durability-critical files: bytes these write must actually reach the
/// disk before a rename publishes them or an `Ok` acknowledges them.
/// The SLO alarm log is in scope — a paged alarm that only ever lived
/// in the page cache is an alarm a crash un-rings.
const FSYNC_SCOPE: &[&str] =
    &["src/index/wal.rs", "src/index/persist.rs", "src/obs/slo.rs"];

/// Modules where `mul_add`/FMA would silently change numeric results
/// between builds (fused vs separate rounding).
const FMA_SCOPE_PREFIXES: &[&str] = &["src/linalg/", "src/tensor/", "src/projections/"];

/// Files where hash-order leaking into output order is a determinism
/// bug: reply assembly, GEMM grouping, snapshot encoding, index scans.
const ITER_SCOPE: &[&str] = &[
    "src/coordinator/server.rs",
    "src/coordinator/state.rs",
    "src/coordinator/net.rs",
    "src/coordinator/batcher.rs",
    "src/coordinator/router.rs",
    "src/runtime/engine.rs",
    "src/obs/registry.rs",
    "src/obs/gemm_stats.rs",
    "src/obs/analyze.rs",
    "src/obs/slo.rs",
];
// The prefix covers the whole index subsystem, WAL included: replay
// order and snapshot bytes must not inherit hash-iteration order.
const ITER_SCOPE_PREFIXES: &[&str] = &["src/index/"];

/// The only modules allowed to contain `unsafe` at all; each block must
/// still carry an adjacent `// SAFETY:` comment.
const UNSAFE_WHITELIST: &[&str] =
    &["src/linalg/gemm.rs", "src/obs/trace.rs", "src/runtime/engine.rs"];

/// Pure counter/gauge modules: every atomic is monotonic bookkeeping
/// read for display, never a cross-thread handoff.
const RELAXED_FILE_ALLOW: &[&str] =
    &["src/coordinator/metrics.rs", "src/obs/registry.rs", "src/obs/gemm_stats.rs"];

/// Identifiers whose `Ordering::Relaxed` use is audited as counter /
/// gauge / watermark traffic, seeded from the metrics and sequencer
/// sites in tree. The sequencer entries (`issued`, `noted`, `covered`,
/// `len`, `active_passes`, `parallel_high_water`) are monotonic
/// watermarks whose cross-thread visibility is anchored by the per-lane
/// turn mutex and the epoch barrier, not by the atomic's own ordering.
const RELAXED_IDENT_ALLOW: &[&str] = &[
    "metrics",
    "submitted",
    "completed",
    "failed",
    "flushes",
    "requests",
    "errors",
    "projects",
    "inserts",
    "queries",
    "deletes",
    "next_flush_id",
    "served",
    "dropped",
    "recorded",
    "written",
    "rotations",
    "issued",
    "noted",
    "covered",
    "len",
    "active_passes",
    "parallel_high_water",
    "GEMM_THREADS",
    "POISON_RECOVERIES",
    "JOBS_PANICKED",
    // WAL watermarks and counters: `wal_seq`/`wal_covered` are per-lane
    // monotonic marks read in-turn (the lane turn mutex anchors their
    // visibility); the rest are display-only metrics.
    "wal_seq",
    "wal_covered",
    "wal_appends",
    "wal_fsyncs",
    "wal_replayed",
    "wal_lag",
    // Trace-context id allocator: a pure monotonic ticket counter whose
    // values are opaque ids — no data is published through it.
    "next_trace_id",
];

fn is_ident_char(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Does `word` appear in `hay` with non-identifier characters (or the
/// text boundary) on both sides?
fn has_word(hay: &str, word: &str) -> bool {
    for (pos, _) in hay.match_indices(word) {
        let before_ok = !hay[..pos].chars().next_back().is_some_and(is_ident_char);
        let after_ok = !hay[pos + word.len()..].chars().next().is_some_and(is_ident_char);
        if before_ok && after_ok {
            return true;
        }
    }
    false
}

/// Index of the first top-level `#[cfg(test)]` line (the unit-test
/// module marker), or `lines.len()` if none. Rules about serving-path
/// behavior stop looking there.
fn test_cutoff(lines: &[StrippedLine]) -> usize {
    lines
        .iter()
        .position(|l| l.code.starts_with("#[cfg(test)]"))
        .unwrap_or(lines.len())
}

fn diag(rule: &'static str, path: &str, line: usize, message: String) -> Diagnostic {
    Diagnostic { rule, path: path.to_string(), line, message }
}

/// Run every rule over one stripped file. `path` is the crate-relative
/// path with forward slashes (e.g. `src/coordinator/state.rs`).
pub fn run_rules(path: &str, lines: &[StrippedLine]) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    float_total_order(path, lines, &mut out);
    no_fma(path, lines, &mut out);
    hot_path_panic(path, lines, &mut out);
    unordered_iteration(path, lines, &mut out);
    unsafe_audit(path, lines, &mut out);
    relaxed_handoff(path, lines, &mut out);
    fsync_discipline(path, lines, &mut out);
    out
}

/// `float-total-order`: `partial_cmp` on floats yields `None` for NaN,
/// and the usual `.unwrap()` chaser turns a poisoned value into a panic
/// mid-sort — or worse, an `unwrap_or(Equal)` silently scrambles the
/// order. `f64::total_cmp` is total, NaN-safe, and bit-identical on the
/// NaN-free data this crate sorts. Benches are exempt (they sort their
/// own timings).
fn float_total_order(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    if path.starts_with("benches/") {
        return;
    }
    for (i, l) in lines.iter().enumerate() {
        if has_word(&l.code, "partial_cmp") {
            out.push(diag(
                "float-total-order",
                path,
                i + 1,
                "partial_cmp on floats is not a total order; use f64::total_cmp".into(),
            ));
        }
    }
}

/// `no-fma`: fused multiply-add rounds once where `a * b + c` rounds
/// twice, so a kernel that picks FMA per-target produces different bits
/// per machine. The numeric core must not use it.
fn no_fma(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    if !FMA_SCOPE_PREFIXES.iter().any(|p| path.starts_with(p)) {
        return;
    }
    for (i, l) in lines.iter().enumerate() {
        if has_word(&l.code, "mul_add") || l.code.contains("fmadd") || l.code.contains("fmsub")
        {
            out.push(diag(
                "no-fma",
                path,
                i + 1,
                "fused multiply-add changes rounding vs mul-then-add; keep the numeric core FMA-free".into(),
            ));
        }
    }
}

/// `hot-path-panic`: a panic in the dispatcher, a lane closure, or the
/// connection loop takes down a worker thread (or poisons a lane mutex)
/// instead of failing one request. Serving code must convert these into
/// error replies or logged degradation.
fn hot_path_panic(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    if !HOT_PATHS.contains(&path) {
        return;
    }
    const PANICKY: &[&str] =
        &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
    let cutoff = test_cutoff(lines);
    for (i, l) in lines.iter().enumerate().take(cutoff) {
        if let Some(p) = PANICKY.iter().find(|p| l.code.contains(**p)) {
            out.push(diag(
                "hot-path-panic",
                path,
                i + 1,
                format!(
                    "{} can panic on the serving path; reply with an error or degrade instead",
                    p.trim_matches(|c| c == '.' || c == '(' || c == ')')
                ),
            ));
        }
    }
}

/// Identifiers bound to a `HashMap`/`HashSet` anywhere in the file:
/// struct fields (`name: HashMap<..>`), lets (`let name = HashMap::..`)
/// and params (`name: &HashMap<..>`). Textual, so a same-named local in
/// another function also matches — that is the conservative direction.
fn hash_bound_idents(lines: &[StrippedLine]) -> Vec<String> {
    let mut idents: Vec<String> = Vec::new();
    for l in lines {
        let code = &l.code;
        let hit = match (code.find("HashMap"), code.find("HashSet")) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (Some(a), None) => Some(a),
            (None, Some(b)) => Some(b),
            (None, None) => None,
        };
        let Some(hit) = hit else { continue };
        let prefix: Vec<char> = code[..hit].chars().collect();
        // Find the last single `:` (not `::`) or bare `=` before the
        // type: that is the binder separating the name from it.
        let mut binder = None;
        for (j, &c) in prefix.iter().enumerate() {
            let prev = if j > 0 { Some(prefix[j - 1]) } else { None };
            let next = prefix.get(j + 1).copied();
            if c == ':' && prev != Some(':') && next != Some(':') {
                binder = Some(j);
            }
            if c == '='
                && !matches!(prev, Some('=' | '!' | '<' | '>' | '+' | '-' | '*' | '/' | '&' | '|' | '^'))
                && !matches!(next, Some('=' | '>'))
            {
                binder = Some(j);
            }
        }
        let Some(binder) = binder else { continue };
        let mut j = binder;
        while j > 0 && prefix[j - 1].is_whitespace() {
            j -= 1;
        }
        let end = j;
        while j > 0 && is_ident_char(prefix[j - 1]) {
            j -= 1;
        }
        let ident: String = prefix[j..end].iter().collect();
        if ident.is_empty()
            || ident.chars().next().is_some_and(|c| c.is_ascii_digit())
            || matches!(ident.as_str(), "let" | "mut" | "pub" | "const" | "static" | "in")
        {
            continue;
        }
        if !idents.contains(&ident) {
            idents.push(ident);
        }
    }
    idents
}

/// `unordered-iteration`: iterating a `HashMap`/`HashSet` yields an
/// arbitrary (per-process!) order. If that order reaches reply
/// assembly, GEMM grouping, or snapshot bytes, identical runs produce
/// different output. Iteration is fine when the result is re-sorted or
/// reduced order-insensitively within the next few lines.
fn unordered_iteration(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    let in_scope = ITER_SCOPE.contains(&path)
        || ITER_SCOPE_PREFIXES.iter().any(|p| path.starts_with(p));
    if !in_scope {
        return;
    }
    const ITER_METHODS: &[&str] = &[
        ".iter()",
        ".iter_mut()",
        ".keys()",
        ".values()",
        ".values_mut()",
        ".into_iter()",
        ".into_keys()",
        ".into_values()",
        ".drain(",
    ];
    // Order-insensitive consumption close by: an explicit re-sort, a
    // BTree re-collect, or a commutative reduction.
    const SETTLES_ORDER: &[&str] =
        &["sort", "BTree", ".max", ".min", ".sum", ".count(", ".any(", ".all(", ".fold(0"];
    let idents = hash_bound_idents(lines);
    if idents.is_empty() {
        return;
    }
    let cutoff = test_cutoff(lines);
    for (i, l) in lines.iter().enumerate().take(cutoff) {
        let code = &l.code;
        let iterates = ITER_METHODS.iter().any(|m| code.contains(m))
            || (code.contains("for ") && code.contains(" in "));
        if !iterates {
            continue;
        }
        let Some(name) = idents.iter().find(|id| has_word(code, id)) else { continue };
        let window: String = lines[i..(i + 4).min(cutoff)]
            .iter()
            .map(|l| l.code.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        if SETTLES_ORDER.iter().any(|s| window.contains(s)) {
            continue;
        }
        out.push(diag(
            "unordered-iteration",
            path,
            i + 1,
            format!(
                "iterating hash container `{name}` in arbitrary order with no nearby sort or order-insensitive reduction"
            ),
        ));
    }
}

/// `unsafe-audit`: `unsafe` may only appear in the three audited
/// modules, and every occurrence needs a `// SAFETY:` justification on
/// the same line or the contiguous comment/attribute block above it.
fn unsafe_audit(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    for (i, l) in lines.iter().enumerate() {
        if !has_word(&l.code, "unsafe") {
            continue;
        }
        if !UNSAFE_WHITELIST.contains(&path) {
            out.push(diag(
                "unsafe-audit",
                path,
                i + 1,
                "unsafe outside the audited modules (linalg/gemm.rs, obs/trace.rs, runtime/engine.rs)".into(),
            ));
            continue;
        }
        let mut justified = l.comment.contains("SAFETY:");
        let mut j = i;
        while !justified && j > 0 && i - j < 15 {
            j -= 1;
            let above = &lines[j];
            if above.comment.contains("SAFETY:") {
                justified = true;
            } else if above.code.trim().is_empty() || above.code.trim_start().starts_with("#[") {
                continue;
            } else {
                break;
            }
        }
        if !justified {
            out.push(diag(
                "unsafe-audit",
                path,
                i + 1,
                "unsafe block without an adjacent `// SAFETY:` comment".into(),
            ));
        }
    }
}

/// `relaxed-handoff`: `Ordering::Relaxed` is correct for counters and
/// gauges but silently wrong on an atomic that *publishes* data to
/// another thread. Any Relaxed use outside the counter modules must
/// touch an audited counter/watermark identifier (the receiver may sit
/// on an earlier line of a split method chain, so a small lookbehind
/// window is searched) or carry a waiver explaining the protocol.
fn relaxed_handoff(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    if RELAXED_FILE_ALLOW.contains(&path) {
        return;
    }
    let cutoff = test_cutoff(lines);
    for (i, l) in lines.iter().enumerate().take(cutoff) {
        if !l.code.contains("Ordering::Relaxed") && !l.code.contains("atomic::Relaxed") {
            continue;
        }
        let window: String = lines[i.saturating_sub(4)..=i]
            .iter()
            .map(|l| l.code.as_str())
            .collect::<Vec<_>>()
            .join("\n");
        if RELAXED_IDENT_ALLOW.iter().any(|id| has_word(&window, id)) {
            continue;
        }
        out.push(diag(
            "relaxed-handoff",
            path,
            i + 1,
            "Ordering::Relaxed on an atomic that is not an audited counter/gauge; use Acquire/Release or waive with the protocol argument".into(),
        ));
    }
}

/// `fsync-discipline`: in the durability-critical files, a rename that
/// publishes freshly written bytes without an intervening `sync_all` /
/// `sync_data` leaves a crash window where the name exists but the
/// content does not — the OS may reorder the metadata commit ahead of
/// the data flush. Likewise `let _ =` on a sync call throws away the
/// only signal that the bytes did NOT reach the platter; durability
/// errors must propagate to the caller. The scan is linear (a sync on
/// any line settles earlier writes), which matches the straight-line
/// write→sync→rename shape both files use.
fn fsync_discipline(path: &str, lines: &[StrippedLine], out: &mut Vec<Diagnostic>) {
    if !FSYNC_SCOPE.contains(&path) {
        return;
    }
    let cutoff = test_cutoff(lines);
    let mut dirty_write: Option<usize> = None;
    for (i, l) in lines.iter().enumerate().take(cutoff) {
        let code = &l.code;
        if code.contains("sync_all") || code.contains("sync_data") {
            if code.trim_start().starts_with("let _ =") {
                out.push(diag(
                    "fsync-discipline",
                    path,
                    i + 1,
                    "fsync result discarded with `let _ =`; a durability error must propagate"
                        .into(),
                ));
            }
            dirty_write = None;
            continue;
        }
        // `.write(true)` is the OpenOptions builder, not a write.
        let writes = code.contains(".write_all(")
            || (code.contains(".write(") && !code.contains(".write(true)"));
        if writes {
            dirty_write = Some(i + 1);
        }
        if code.contains("rename(") {
            if let Some(w) = dirty_write.take() {
                out.push(diag(
                    "fsync-discipline",
                    path,
                    i + 1,
                    format!(
                        "rename publishes bytes written at line {w} with no intervening \
                         sync_all/sync_data; a crash can leave the name without the content"
                    ),
                ));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::lexer::strip;
    use super::*;

    fn run_on(path: &str, src: &str) -> Vec<Diagnostic> {
        run_rules(path, &strip(src))
    }

    fn rules_of(diags: &[Diagnostic]) -> Vec<&str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn float_total_order_flags_partial_cmp_but_not_benches() {
        let src = "v.sort_by(|a, b| a.partial_cmp(b).unwrap());\n";
        assert_eq!(rules_of(&run_on("src/util/stats.rs", src)), vec!["float-total-order"]);
        assert!(run_on("benches/fig2.rs", src).is_empty());
        assert!(run_on("src/util/stats.rs", "v.sort_by(f64::total_cmp);\n").is_empty());
    }

    #[test]
    fn partial_cmp_in_comment_or_string_is_ignored() {
        let src = "// partial_cmp was here\nlet s = \"partial_cmp\";\n";
        assert!(run_on("src/util/stats.rs", src).is_empty());
    }

    #[test]
    fn no_fma_scoped_to_numeric_core() {
        let src = "let y = a.mul_add(b, c);\n";
        assert_eq!(rules_of(&run_on("src/linalg/gemm2.rs", src)), vec!["no-fma"]);
        assert!(run_on("src/coordinator/router2.rs", src).is_empty());
    }

    #[test]
    fn hot_path_panic_flags_unwrap_before_tests_only() {
        let src = "let x = rx.recv().unwrap();\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\n";
        let d = run_on("src/coordinator/net.rs", src);
        assert_eq!(rules_of(&d), vec!["hot-path-panic"]);
        assert_eq!(d[0].line, 1);
        assert!(run_on("src/index/flat.rs", "x.unwrap();\n").is_empty());
    }

    #[test]
    fn unwrap_or_variants_do_not_trip_hot_path_panic() {
        let src = "let x = m.get(&k).copied().unwrap_or(0);\nlet y = o.unwrap_or_else(Vec::new);\n";
        assert!(run_on("src/coordinator/net.rs", src).is_empty());
    }

    #[test]
    fn unordered_iteration_flags_unsorted_hash_walk() {
        let src = "struct S { table: HashMap<u32, u32> }\nfor v in self.table.values() {\n    emit(v);\n}\n";
        let d = run_on("src/coordinator/router.rs", src);
        assert_eq!(rules_of(&d), vec!["unordered-iteration"]);
        assert_eq!(d[0].line, 2);
    }

    #[test]
    fn unordered_iteration_settled_by_sort_or_reduction() {
        let sorted = "struct S { table: HashMap<u32, u32> }\nlet mut v: Vec<u32> = table.values().copied().collect();\nv.sort();\n";
        assert!(run_on("src/coordinator/router.rs", sorted).is_empty());
        let reduced = "struct S { table: HashMap<u32, u32> }\nlet top = table.values().max();\n";
        assert!(run_on("src/coordinator/router.rs", reduced).is_empty());
    }

    #[test]
    fn unsafe_audit_whitelist_and_safety_comment() {
        let bare = "unsafe { do_it() };\n";
        assert_eq!(rules_of(&run_on("src/coordinator/server.rs", bare)), vec!["unsafe-audit"]);
        assert_eq!(rules_of(&run_on("src/linalg/gemm.rs", bare)), vec!["unsafe-audit"]);
        let justified = "// SAFETY: bounds checked above.\nunsafe { do_it() };\n";
        assert!(run_on("src/linalg/gemm.rs", justified).is_empty());
        let through_attr = "// SAFETY: caller checks avx2.\n#[target_feature(enable = \"avx2\")]\nunsafe fn k() {}\n";
        assert!(run_on("src/linalg/gemm.rs", through_attr).is_empty());
    }

    #[test]
    fn relaxed_handoff_allows_counters_flags_handoffs() {
        let counter = "self.metrics.submitted.fetch_add(1, Ordering::Relaxed);\n";
        assert!(run_on("src/coordinator/server.rs", counter).is_empty());
        let split = "shared\n    .metrics\n    .native_flush_max\n    .store(v, Ordering::Relaxed);\n";
        assert!(run_on("src/coordinator/server.rs", split).is_empty());
        let handoff = "self.ready_flag.store(true, Ordering::Relaxed);\n";
        assert_eq!(
            rules_of(&run_on("src/coordinator/server.rs", handoff)),
            vec!["relaxed-handoff"]
        );
    }

    #[test]
    fn fsync_discipline_requires_sync_between_write_and_rename() {
        let dirty = "f.write_all(&buf)?;\nstd::fs::rename(&tmp, &path)?;\n";
        let d = run_on("src/index/persist.rs", dirty);
        assert_eq!(rules_of(&d), vec!["fsync-discipline"]);
        assert_eq!(d[0].line, 2);
        let synced =
            "f.write_all(&buf)?;\nf.sync_all().map_err(|e| e.to_string())?;\nstd::fs::rename(&tmp, &path)?;\n";
        assert!(run_on("src/index/persist.rs", synced).is_empty());
        // Out of scope: ordinary files may rename freely.
        assert!(run_on("src/util/csv.rs", dirty).is_empty());
    }

    #[test]
    fn fsync_discipline_flags_discarded_sync_result() {
        let src = "let _ = dir.sync_all();\n";
        assert_eq!(rules_of(&run_on("src/index/wal.rs", src)), vec!["fsync-discipline"]);
        assert!(run_on("src/index/wal.rs", "dir.sync_all().map_err(|e| e.to_string())?;\n")
            .is_empty());
        // The OpenOptions builder's `.write(true)` is not a write.
        let open =
            "let f = OpenOptions::new().write(true).open(&p)?;\nstd::fs::rename(&p, &q)?;\n";
        assert!(run_on("src/index/wal.rs", open).is_empty());
    }

    #[test]
    fn hash_bound_idents_sees_fields_lets_and_params() {
        let lines = strip(
            "struct S { by_id: HashMap<u64, usize> }\nlet mut seen = HashSet::new();\nfn f(m: &HashMap<u32, u32>) {}\nuse std::collections::HashMap;\n",
        );
        let ids = hash_bound_idents(&lines);
        assert!(ids.contains(&"by_id".to_string()));
        assert!(ids.contains(&"seen".to_string()));
        assert!(ids.contains(&"m".to_string()));
        assert!(!ids.contains(&"collections".to_string()));
    }
}
