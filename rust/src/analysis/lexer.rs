//! Comment- and literal-stripping lexer over Rust source text.
//!
//! The rule engine must never match on comment text or string contents:
//! a rule id mentioned in prose, or `"panic!"` inside a log message, is
//! not a violation. Each source line is therefore split into the *code*
//! that survives stripping and the *comment* text found on it. Literal
//! bodies are blanked but their delimiters stay (`"a,b"` becomes `""`)
//! so surrounding expressions still read as expressions; raw strings
//! collapse to `""`; lifetimes are distinguished from char literals so
//! `&'a str` survives intact. Block comments — including nested ones —
//! and multi-line string literals carry their state across lines.

/// One source line, split into stripped code and comment text.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct StrippedLine {
    /// Code with comments removed and literal bodies blanked.
    pub code: String,
    /// Concatenated text of every comment on the line.
    pub comment: String,
}

enum Mode {
    Code,
    LineComment,
    /// Nesting depth (Rust block comments nest).
    BlockComment(u32),
    Str,
    /// Number of `#`s in the raw-string fence.
    RawStr(u32),
}

/// Does the raw-string opener `r#*"` (or `br#*"`) start at `i`?
/// Returns `(hashes, chars_to_skip)` covering the opening quote.
fn raw_open(b: &[char], i: usize) -> Option<(u32, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0u32;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

fn ends_in_ident(code: &str) -> bool {
    code.chars().next_back().is_some_and(|c| c.is_alphanumeric() || c == '_')
}

/// Strip `source` into per-line code/comment views (1-based indexing is
/// the caller's job: `lines[n - 1]` is source line `n`).
pub fn strip(source: &str) -> Vec<StrippedLine> {
    let b: Vec<char> = source.chars().collect();
    let mut out = Vec::new();
    let mut line = StrippedLine::default();
    let mut mode = Mode::Code;
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c == '\n' {
            if matches!(mode, Mode::LineComment) {
                mode = Mode::Code;
            }
            out.push(std::mem::take(&mut line));
            i += 1;
            continue;
        }
        match mode {
            Mode::LineComment => {
                line.comment.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '*' && b.get(i + 1) == Some(&'/') {
                    // Inner closers stay visible in the comment text;
                    // only the outermost one ends the comment.
                    if depth <= 1 {
                        mode = Mode::Code;
                    } else {
                        line.comment.push_str("*/");
                        mode = Mode::BlockComment(depth - 1);
                    }
                    i += 2;
                } else if c == '/' && b.get(i + 1) == Some(&'*') {
                    line.comment.push_str("/*");
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    line.comment.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // Skip the escaped char; a trailing `\` before the
                    // newline is Rust's line continuation.
                    if b.get(i + 1) == Some(&'\n') {
                        out.push(std::mem::take(&mut line));
                    }
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut n = 0u32;
                    while n < hashes && b.get(i + 1 + n as usize) == Some(&'#') {
                        n += 1;
                    }
                    if n == hashes {
                        line.code.push('"');
                        mode = Mode::Code;
                        i += 1 + hashes as usize;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
            Mode::Code => {
                let next = b.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    mode = Mode::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    mode = Mode::BlockComment(1);
                    i += 2;
                } else if c == '"' {
                    line.code.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !ends_in_ident(&line.code) {
                    if let Some((hashes, skip)) = raw_open(&b, i) {
                        line.code.push('"');
                        mode = Mode::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && next == Some('"') {
                        line.code.push('"');
                        mode = Mode::Str;
                        i += 2;
                    } else {
                        line.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Lifetime or char literal? After the quote: `\` means
                    // an escaped char literal; `x'` means a plain one;
                    // anything else is a lifetime (`&'a`, `'static`).
                    match (next, b.get(i + 2).copied()) {
                        (Some('\\'), third) => {
                            // `'\n'`, `'\''`, `'\u{1F600}'`: find the close
                            // quote past the escape.
                            let mut j = i + 3;
                            if third == Some('u') && b.get(i + 3) == Some(&'{') {
                                while j < b.len() && b[j] != '}' {
                                    j += 1;
                                }
                                j += 1;
                            }
                            if b.get(j) == Some(&'\'') {
                                line.code.push_str("''");
                                i = j + 1;
                            } else {
                                line.code.push('\'');
                                i += 1;
                            }
                        }
                        (Some(nc), Some('\'')) if nc != '\'' && nc != '\n' => {
                            line.code.push_str("''");
                            i += 3;
                        }
                        _ => {
                            line.code.push('\'');
                            i += 1;
                        }
                    }
                } else {
                    line.code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !line.code.is_empty() || !line.comment.is_empty() {
        out.push(line);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn code_of(src: &str) -> Vec<String> {
        strip(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn line_comments_move_to_comment_channel() {
        let lines = strip("let x = 1; // trailing note\n// full-line note\n");
        assert_eq!(lines[0].code, "let x = 1; ");
        assert_eq!(lines[0].comment, " trailing note");
        assert_eq!(lines[1].code, "");
        assert_eq!(lines[1].comment, " full-line note");
    }

    #[test]
    fn string_bodies_are_blanked() {
        let c = code_of("let s = \"panic!(boom) // not code\";\n");
        assert_eq!(c[0], "let s = \"\";");
    }

    #[test]
    fn escaped_quotes_stay_inside_the_string() {
        let c = code_of(r#"let s = "a\"b"; let t = 2;"#);
        assert_eq!(c[0], r#"let s = ""; let t = 2;"#);
    }

    #[test]
    fn raw_strings_collapse() {
        let c = code_of("let s = r#\"has \"quotes\" and // slashes\"#; done();\n");
        assert_eq!(c[0], "let s = \"\"; done();");
    }

    #[test]
    fn nested_block_comments_end_correctly() {
        let lines = strip("a /* x /* y */ z */ b\n");
        assert_eq!(lines[0].code, "a  b");
        assert_eq!(lines[0].comment, " x /* y */ z ");
    }

    #[test]
    fn block_comment_spans_lines() {
        let lines = strip("before /* one\ntwo */ after\n");
        assert_eq!(lines[0].code, "before ");
        assert_eq!(lines[1].code, " after");
        assert_eq!(lines[1].comment, "two ");
    }

    #[test]
    fn lifetimes_survive_char_literals_blank() {
        let c = code_of("fn f<'a>(s: &'a str) -> char { 'x' }\nlet n = '\\n'; let q = '\\'';\n");
        assert_eq!(c[0], "fn f<'a>(s: &'a str) -> char { '' }");
        assert_eq!(c[1], "let n = ''; let q = '';");
    }

    #[test]
    fn multiline_string_keeps_line_count() {
        let lines = strip("let s = \"one\ntwo\nthree\"; end();\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].code, "let s = \"");
        assert_eq!(lines[1].code, "");
        assert_eq!(lines[2].code, "\"; end();");
    }

    #[test]
    fn identifier_ending_in_r_does_not_open_raw_string() {
        // `for` ends in `r`; the quote after it is a plain string.
        let c = code_of("for x in var\"\".chars() {}\n");
        assert_eq!(c[0], "for x in var\"\".chars() {}");
    }
}
